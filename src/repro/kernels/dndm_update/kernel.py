"""Fused DNDM transition update — Pallas kernel.

The inner loop of Algorithm 1/3 is: decode x0_hat = argmax_K(logits) and
apply eq. (9): x_{t-1} = where(tau == t, x0_hat, x_t) (or tau >= t for
Algorithm 3).  Done naively this materializes the (B, N, K) softmax/argmax
intermediate in HBM; fused, it is one streaming pass: logits tiles are
consumed block-by-block over the vocab with a running (max, argmax) pair
in VMEM, and the token update happens in-register on the last vocab block.

grid = (B, num_token_blocks, num_vocab_blocks), vocab innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dndm_kernel(logits_ref, x_ref, tau_ref, t_ref, o_ref,
                 m_scr, idx_scr, *, nk: int, bkv: int, version: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        idx_scr[...] = jnp.zeros_like(idx_scr)

    blk = logits_ref[0].astype(jnp.float32)             # (bn, bkv)
    local_max = blk.max(axis=1)
    local_arg = blk.argmax(axis=1).astype(jnp.int32) + ik * bkv
    better = local_max > m_scr[...]
    m_scr[...] = jnp.where(better, local_max, m_scr[...])
    idx_scr[...] = jnp.where(better, local_arg, idx_scr[...])

    @pl.when(ik == nk - 1)
    def _flush():
        x = x_ref[0]
        tau = tau_ref[0]
        t = t_ref[0]
        cond = (tau == t) if version == 1 else (tau >= t)
        o_ref[0] = jnp.where(cond, idx_scr[...], x)


def dndm_update_kernel(logits, x, tau, t, *, version: int = 1,
                       block_n: int = 256, block_v: int = 1024,
                       interpret: bool = True):
    """logits: (B,N,K); x, tau: (B,N) int32; t: (1,) int32.
    Returns updated tokens (B,N) int32."""
    B, N, K = logits.shape
    bn = min(block_n, N)
    bkv = min(block_v, K)
    if N % bn or K % bkv:
        raise ValueError(f"(N,K)=({N},{K}) must divide blocks ({bn},{bkv})")
    nn, nk = N // bn, K // bkv

    return pl.pallas_call(
        functools.partial(_dndm_kernel, nk=nk, bkv=bkv, version=version),
        grid=(B, nn, nk),
        in_specs=[
            pl.BlockSpec((1, bn, bkv), lambda b, i, k: (b, i, k)),
            pl.BlockSpec((1, bn), lambda b, i, k: (b, i)),
            pl.BlockSpec((1, bn), lambda b, i, k: (b, i)),
            pl.BlockSpec((1,), lambda b, i, k: (0,)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda b, i, k: (b, i)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((bn,), jnp.float32),
            pltpu.VMEM((bn,), jnp.int32),
        ],
        interpret=interpret,
    )(logits, x, tau, t)
