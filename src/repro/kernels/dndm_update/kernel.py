"""Fused DNDM decode-update — Pallas kernel.

The inner loop of Algorithm 1/3 is: decode x0_hat from the logits and
apply eq. (9): x_{t-1} = where(tau == t, x0_hat, x_t) (``tau >= t`` for
Algorithm 3).  Done naively this materializes the (B, N, K) softmax /
argmax intermediate in HBM; fused, it is one streaming pass: logit tiles
are consumed block-by-block over the vocab with a running (max, argmax)
pair in VMEM, and the token update happens in-register on the last vocab
block.

Two decode modes share the same streaming loop:

  * argmax — x0_hat = argmax_K(logits / temp + mask);
  * sample — Gumbel-max: x0_hat = argmax_K(logits / temp + mask + g)
    with g ~ Gumbel(0, 1) supplied as a tile-streamed input, so every
    backend (compiled, interpret, pure-JAX reference) sees identical
    noise and the decoded tokens match bitwise under a fixed key.

The additive ``mask`` row (shape (1, K)) carries the noise distribution's
forbidden-token penalty (e.g. never decode [MASK] as a clean token).

grid = (B, num_token_blocks, num_vocab_blocks), vocab innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dndm_kernel(*refs, nk: int, bkv: int, version: int,
                 temperature: float, has_gumbel: bool):
    if has_gumbel:
        (logits_ref, gumbel_ref, mask_ref, x_ref, tau_ref, t_ref, o_ref,
         m_scr, idx_scr) = refs
    else:
        (logits_ref, mask_ref, x_ref, tau_ref, t_ref, o_ref,
         m_scr, idx_scr) = refs
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        idx_scr[...] = jnp.zeros_like(idx_scr)

    # NOTE: op order (cast, /temp, +mask, +gumbel) must stay in lockstep
    # with ref.adjust_logits — bitwise token parity depends on it.
    a = logits_ref[0].astype(jnp.float32)               # (bn, bkv)
    if temperature != 1.0:
        a = a / temperature
    a = a + mask_ref[0]                                 # (bkv,) broadcast
    if has_gumbel:
        a = a + gumbel_ref[0]
    local_max = a.max(axis=1)
    local_arg = a.argmax(axis=1).astype(jnp.int32) + ik * bkv
    better = local_max > m_scr[...]
    m_scr[...] = jnp.where(better, local_max, m_scr[...])
    idx_scr[...] = jnp.where(better, local_arg, idx_scr[...])

    @pl.when(ik == nk - 1)
    def _flush():
        x = x_ref[0]
        tau = tau_ref[0]
        t = t_ref[0]
        cond = (tau == t) if version == 1 else (tau >= t)
        o_ref[0] = jnp.where(cond, idx_scr[...], x)


def dndm_update_kernel(logits, mask, x, tau, t, gumbel=None, *,
                       version: int = 1, temperature: float = 1.0,
                       block_n: int = 256, block_v: int = 1024,
                       interpret: bool = True):
    """logits: (B,N,K); mask: (1,K) f32; x, tau: (B,N) int32; t: (1,) int32;
    gumbel: optional (B,N,K) f32.  Returns updated tokens (B,N) int32."""
    B, N, K = logits.shape
    bn = min(block_n, N)
    bkv = min(block_v, K)
    if N % bn or K % bkv:
        raise ValueError(f"(N,K)=({N},{K}) must divide blocks ({bn},{bkv}); "
                         "use ops.dndm_update, which pads")
    nn, nk = N // bn, K // bkv

    logit_spec = pl.BlockSpec((1, bn, bkv), lambda b, i, k: (b, i, k))
    in_specs = [logit_spec]
    args = [logits]
    if gumbel is not None:
        in_specs.append(logit_spec)
        args.append(gumbel)
    in_specs += [
        pl.BlockSpec((1, bkv), lambda b, i, k: (0, k)),
        pl.BlockSpec((1, bn), lambda b, i, k: (b, i)),
        pl.BlockSpec((1, bn), lambda b, i, k: (b, i)),
        pl.BlockSpec((1,), lambda b, i, k: (0,)),
    ]
    args += [mask, x, tau, t]

    return pl.pallas_call(
        functools.partial(_dndm_kernel, nk=nk, bkv=bkv, version=version,
                          temperature=temperature,
                          has_gumbel=gumbel is not None),
        grid=(B, nn, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bn), lambda b, i, k: (b, i)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((bn,), jnp.float32),
            pltpu.VMEM((bn,), jnp.int32),
        ],
        interpret=interpret,
    )(*args)
