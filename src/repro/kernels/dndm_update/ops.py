"""jit'd wrapper for the fused DNDM decode-update.

Pads N and K up to TPU-friendly block multiples (8-sublane / 128-lane
granularity) instead of raising on non-divisible shapes, and auto-detects
the execution backend: compiled Mosaic on TPU, the Pallas interpreter
elsewhere (``interpret=None``, the default).  Pass ``interpret`` explicitly
to force either mode.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro import obs
from repro.kernels.dndm_update.kernel import dndm_update_kernel


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def record_padding(kernel: str, N: int, K: int, pad_n: int,
                   pad_k: int) -> None:
    """Padding-overhead gauges for a kernel call.  Shapes are static, so
    when the op is jitted this runs at trace time: one record per
    compiled program, describing the waste baked into it."""
    if not obs.enabled():
        return
    total = (N + pad_n) * (K + pad_k)
    obs.counter("kernel.traces").inc(kernel=kernel)
    obs.gauge("kernel.pad_n").set(pad_n, kernel=kernel)
    obs.gauge("kernel.pad_k").set(pad_k, kernel=kernel)
    obs.gauge("kernel.pad_fraction",
              "fraction of padded (N+pad)(K+pad) elements that is waste"
              ).set(round(1.0 - (N * K) / total, 6), kernel=kernel)


def default_interpret() -> bool:
    """Compiled on TPU, interpret everywhere else."""
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("version", "block_n", "block_v",
                                   "temperature", "interpret"))
def dndm_update(logits, x, tau, t, *, mask=None, gumbel=None,
                version: int = 1, block_n: int = 256, block_v: int = 1024,
                temperature: float = 1.0, interpret: bool | None = None):
    """logits: (B,N,K); x, tau: (B,N) int32; t scalar int32.

    Optional ``mask`` (K,) f32 additive logit penalty and ``gumbel``
    (B,N,K) f32 noise (sample mode).  Returns updated tokens (B,N) int32.
    """
    if interpret is None:
        interpret = default_interpret()
    B, N, K = logits.shape
    bn = min(block_n, _round_up(N, 8))
    bkv = min(block_v, _round_up(K, 128))
    pad_n = _round_up(N, bn) - N
    pad_k = _round_up(K, bkv) - K
    record_padding("dndm_update", N, K, pad_n, pad_k)
    if mask is None:
        mask = jnp.zeros((K,), jnp.float32)
    mask = mask.astype(jnp.float32).reshape(1, K)
    if pad_n:
        logits = jnp.pad(logits, ((0, 0), (0, pad_n), (0, 0)))
        x = jnp.pad(x, ((0, 0), (0, pad_n)))
        tau = jnp.pad(tau, ((0, 0), (0, pad_n)))
        if gumbel is not None:
            gumbel = jnp.pad(gumbel, ((0, 0), (0, pad_n), (0, 0)))
    if pad_k:
        # -inf keeps padded vocab lanes out of the running max; gumbel and
        # mask pad with 0 so the padded lanes stay at exactly -inf.
        logits = jnp.pad(logits, ((0, 0), (0, 0), (0, pad_k)),
                         constant_values=-jnp.inf)
        mask = jnp.pad(mask, ((0, 0), (0, pad_k)))
        if gumbel is not None:
            gumbel = jnp.pad(gumbel, ((0, 0), (0, 0), (0, pad_k)))
    t_arr = jnp.asarray(t, jnp.int32).reshape(1)
    out = dndm_update_kernel(logits, mask, x.astype(jnp.int32),
                             tau.astype(jnp.int32), t_arr,
                             gumbel=gumbel, version=version,
                             temperature=temperature, block_n=bn,
                             block_v=bkv, interpret=interpret)
    return out[:, :N]
