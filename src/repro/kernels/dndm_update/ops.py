"""jit'd wrapper for the fused DNDM update (pads N and K to blocks)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.dndm_update.kernel import dndm_update_kernel


@partial(jax.jit, static_argnames=("version", "block_n", "block_v",
                                   "interpret"))
def dndm_update(logits, x, tau, t, *, version: int = 1, block_n: int = 256,
                block_v: int = 1024, interpret: bool = True):
    """logits: (B,N,K); x, tau: (B,N) int32; t scalar int32."""
    B, N, K = logits.shape
    bn = min(block_n, N)
    bkv = min(block_v, K)
    pad_n = (-N) % bn
    pad_k = (-K) % bkv
    if pad_n:
        logits = jnp.pad(logits, ((0, 0), (0, pad_n), (0, 0)))
        x = jnp.pad(x, ((0, 0), (0, pad_n)))
        tau = jnp.pad(tau, ((0, 0), (0, pad_n)))
    if pad_k:
        logits = jnp.pad(logits, ((0, 0), (0, 0), (0, pad_k)),
                         constant_values=-jnp.inf)
    t_arr = jnp.asarray(t, jnp.int32).reshape(1)
    out = dndm_update_kernel(logits, x.astype(jnp.int32),
                             tau.astype(jnp.int32), t_arr, version=version,
                             block_n=bn, block_v=bkv, interpret=interpret)
    return out[:, :N]
