"""Pure-jnp oracle for the fused DNDM transition update."""
from __future__ import annotations

import jax.numpy as jnp


def dndm_update_ref(logits, x, tau, t, *, version: int = 1):
    """logits: (B,N,K); x, tau: (B,N); t: (1,) — eq. (9) with argmax x0."""
    x0_hat = logits.argmax(-1).astype(jnp.int32)
    cond = (tau == t[0]) if version == 1 else (tau >= t[0])
    return jnp.where(cond, x0_hat, x)
