"""Pure-jnp oracle for the fused DNDM decode-update."""
from __future__ import annotations

import jax.numpy as jnp


def adjust_logits(logits, mask=None, temperature: float = 1.0, gumbel=None):
    """The decode pre-activation: f32 cast, temperature, additive mask,
    optional Gumbel noise.  Op order must stay in lockstep with the Pallas
    kernel — bitwise token parity across backends depends on it."""
    a = logits.astype(jnp.float32)
    if temperature != 1.0:
        a = a / temperature
    if mask is not None:
        a = a + mask
    if gumbel is not None:
        a = a + gumbel
    return a


def dndm_update_ref(logits, x, tau, t, *, version: int = 1, mask=None,
                    temperature: float = 1.0, gumbel=None):
    """logits: (B,N,K); x, tau: (B,N); t: (1,) — eq. (9) with argmax
    (or Gumbel-max when ``gumbel`` is given) x0."""
    a = adjust_logits(logits, mask=mask, temperature=temperature,
                      gumbel=gumbel)
    x0_hat = a.argmax(-1).astype(jnp.int32)
    cond = (tau == t[0]) if version == 1 else (tau >= t[0])
    return jnp.where(cond, x0_hat, x)
