from repro.kernels.dndm_update import ops, ref
from repro.kernels.dndm_update.kernel import dndm_update_kernel

__all__ = ["ops", "ref", "dndm_update_kernel"]
