"""jit'd public wrapper for the flash-attention kernel.

Accepts the model's (B, S, H, hd) layout, reorders to the kernel's
(B, H, S, hd), and pads sequence lengths up to block multiples (padded
keys are masked with NEG bias; padded queries are sliced off).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import NEG, flash_attention_kernel


@partial(jax.jit, static_argnames=("block_q", "block_k", "interpret"))
def flash_attention(q, k, v, bias, *, block_q: int = 256,
                    block_k: int = 256, interpret: bool = True):
    """q,k,v: (B,S,H,hd) (kv heads already repeated); bias: (B,Sq,Sk).
    Returns (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    pad_q = (-Sq) % bq
    pad_k = (-Sk) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        bias = jnp.pad(bias, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        bias = jnp.pad(bias, ((0, 0), (0, 0), (0, pad_k)),
                       constant_values=NEG)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_attention_kernel(qt, kt, vt, bias, block_q=bq, block_k=bk,
                               interpret=interpret)
    o = o.transpose(0, 2, 1, 3)
    return o[:, :Sq]
