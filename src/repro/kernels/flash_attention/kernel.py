"""Flash-attention Pallas kernel (TPU target, interpret-validated on CPU).

Online-softmax attention with explicit VMEM tiling:
  grid = (B, H, num_q_blocks, num_k_blocks); the k dimension is the
  innermost (sequential) axis, so the running (m, l, acc) statistics live
  in VMEM scratch across k iterations and the output tile is written once
  on the last k block.  Default blocks 256x256 with head_dim lanes —
  contracting dims MXU-aligned for hd in {64, 128}.

Layout: q, k, v are (B, H, S, hd); the additive bias (mask) is (B, Sq, Sk)
shared across heads — the ops wrapper materializes causal / sliding-window
masks or forwards user bias.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e9


def _flash_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale: float, nk: int):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)          # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)          # (bk, hd)
    bias = bias_ref[0].astype(jnp.float32)       # (bq, bk)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale + bias
    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_prev * alpha + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _flush():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, bias, *, block_q: int = 256,
                           block_k: int = 256, interpret: bool = True):
    """q,k,v: (B,H,S,hd); bias: (B,Sq,Sk).  Returns (B,H,Sq,hd)."""
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    if Sq % bq or Sk % bk:
        raise ValueError(f"S ({Sq},{Sk}) must divide blocks ({bq},{bk})")
    nq, nk = Sq // bq, Sk // bk
    scale = 1.0 / (hd ** 0.5)

    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, nk=nk),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, bq, bk), lambda b, h, iq, ik: (b, iq, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max m
            pltpu.VMEM((bq,), jnp.float32),      # running sum l
            pltpu.VMEM((bq, hd), jnp.float32),   # accumulator
        ],
        interpret=interpret,
    )(q, k, v, bias)
