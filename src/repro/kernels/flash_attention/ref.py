"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, bias):
    """q,k,v: (B,H,S,hd); bias: (B,Sq,Sk) additive.  fp32 softmax."""
    hd = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (hd ** 0.5)
    s = s + bias[:, None].astype(jnp.float32)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
