from repro.kernels.flash_attention import ops, ref
from repro.kernels.flash_attention.kernel import flash_attention_kernel

__all__ = ["ops", "ref", "flash_attention_kernel"]
