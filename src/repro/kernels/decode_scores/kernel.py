"""Streaming (token, score) decode — Pallas kernel.

The confidence-ranked samplers (DNDM-K, RDM-k, Mask-Predict, DDIM,
DNDM-C) need more than the argmax token: they rank positions by the
log-probability of the decoded token.  Done naively that materializes the
full (B, N, K) log-softmax in HBM and gathers out of it.  Fused, it is
the same streaming pass as ``dndm_update``: logit tiles are consumed
block-by-block over the vocab with a flash-attention-style online
logsumexp, and both outputs fall out on the last vocab tile:

  * token — running (max, argmax) over the *selection* activation
    ``sel = logits/temp + mask (+ gumbel)``, identical op order to
    ``dndm_update`` / ``ref.adjust_logits`` so tokens stay bitwise equal
    across every backend;
  * score — ``a[token] - logsumexp(a)`` where ``a`` is the adjusted
    logit *without* the Gumbel noise (the rank key is the model's
    log-probability of the chosen token, not the perturbed value).
    ``logsumexp(a)`` is accumulated online as a running (m, sum) pair in
    VMEM; ``a[token]`` is tracked alongside the running argmax.

Nothing of shape (B, N, K) is ever written back to HBM.

grid = (B, num_token_blocks, num_vocab_blocks), vocab innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _decode_scores_kernel(*refs, nk: int, bkv: int, temperature: float,
                          has_gumbel: bool):
    if has_gumbel:
        (logits_ref, gumbel_ref, mask_ref, tok_ref, score_ref,
         sel_m, sel_idx, a_tok, lse_m, lse_s) = refs
    else:
        (logits_ref, mask_ref, tok_ref, score_ref,
         sel_m, sel_idx, a_tok, lse_m, lse_s) = refs
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        sel_m[...] = jnp.full_like(sel_m, -jnp.inf)
        sel_idx[...] = jnp.zeros_like(sel_idx)
        a_tok[...] = jnp.full_like(a_tok, -jnp.inf)
        lse_m[...] = jnp.full_like(lse_m, -jnp.inf)
        lse_s[...] = jnp.zeros_like(lse_s)

    # NOTE: op order (cast, /temp, +mask, +gumbel) must stay in lockstep
    # with ref.adjust_logits — bitwise token parity depends on it.
    a = logits_ref[0].astype(jnp.float32)               # (bn, bkv)
    if temperature != 1.0:
        a = a / temperature
    a = a + mask_ref[0]                                 # (bkv,) broadcast
    sel = a + gumbel_ref[0] if has_gumbel else a

    local_max = sel.max(axis=1)
    local_arg = sel.argmax(axis=1).astype(jnp.int32)
    # adjusted (noise-free) logit at this tile's winner, via one-hot max —
    # no gather along lanes on TPU
    lane = jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
    a_local = jnp.where(lane == local_arg[:, None], a, -jnp.inf).max(axis=1)
    better = local_max > sel_m[...]
    sel_m[...] = jnp.where(better, local_max, sel_m[...])
    sel_idx[...] = jnp.where(better, local_arg + ik * bkv, sel_idx[...])
    a_tok[...] = jnp.where(better, a_local, a_tok[...])

    # online logsumexp over a (padded vocab lanes sit at -inf => exp == 0)
    m_new = jnp.maximum(lse_m[...], a.max(axis=1))
    lse_s[...] = (lse_s[...] * jnp.exp(lse_m[...] - m_new)
                  + jnp.exp(a - m_new[:, None]).sum(axis=1))
    lse_m[...] = m_new

    @pl.when(ik == nk - 1)
    def _flush():
        tok_ref[0] = sel_idx[...]
        score_ref[0] = a_tok[...] - (lse_m[...] + jnp.log(lse_s[...]))


def decode_scores_kernel(logits, mask, gumbel=None, *,
                         temperature: float = 1.0, block_n: int = 256,
                         block_v: int = 1024, interpret: bool = True):
    """logits: (B,N,K); mask: (1,K) f32; gumbel: optional (B,N,K) f32.
    Returns (tokens (B,N) int32, scores (B,N) f32)."""
    B, N, K = logits.shape
    bn = min(block_n, N)
    bkv = min(block_v, K)
    if N % bn or K % bkv:
        raise ValueError(f"(N,K)=({N},{K}) must divide blocks ({bn},{bkv}); "
                         "use ops.decode_scores, which pads")
    nn, nk = N // bn, K // bkv

    logit_spec = pl.BlockSpec((1, bn, bkv), lambda b, i, k: (b, i, k))
    in_specs = [logit_spec]
    args = [logits]
    if gumbel is not None:
        in_specs.append(logit_spec)
        args.append(gumbel)
    in_specs.append(pl.BlockSpec((1, bkv), lambda b, i, k: (0, k)))
    args.append(mask)

    out_spec = pl.BlockSpec((1, bn), lambda b, i, k: (b, i))
    return pl.pallas_call(
        functools.partial(_decode_scores_kernel, nk=nk, bkv=bkv,
                          temperature=temperature,
                          has_gumbel=gumbel is not None),
        grid=(B, nn, nk),
        in_specs=in_specs,
        out_specs=(out_spec, out_spec),
        out_shape=(jax.ShapeDtypeStruct((B, N), jnp.int32),
                   jax.ShapeDtypeStruct((B, N), jnp.float32)),
        scratch_shapes=[
            pltpu.VMEM((bn,), jnp.float32),     # running selection max
            pltpu.VMEM((bn,), jnp.int32),       # running argmax
            pltpu.VMEM((bn,), jnp.float32),     # adjusted logit at argmax
            pltpu.VMEM((bn,), jnp.float32),     # logsumexp running max
            pltpu.VMEM((bn,), jnp.float32),     # logsumexp running sum
        ],
        interpret=interpret,
    )(*args)
