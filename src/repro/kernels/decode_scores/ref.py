"""Pure-jnp oracle for the streaming (token, score) decode."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.dndm_update.ref import adjust_logits


def decode_scores_ref(logits, *, mask=None, temperature: float = 1.0,
                      gumbel=None):
    """logits: (B,N,K) -> (tokens (B,N) int32, scores (B,N) f32).

    Tokens are the argmax of the adjusted logits (+ Gumbel noise in
    sample mode) — the same selection ``dndm_update`` applies, so tokens
    agree bitwise with both ``fused_update`` and the streaming kernel.
    Scores are the log-softmax of the *noise-free* adjusted logits at the
    chosen token (the confidence the top-k samplers rank on), computed
    with the kernel's exact float association — ``a[tok] - (m + log(s))``
    with ``m = max(a)``, ``s = sum(exp(a - m))`` — NOT via
    ``jax.nn.log_softmax`` (which groups as ``(a[tok] - m) - log(s)`` and
    drifts by an ulp).  Keeping the association in lockstep makes scores,
    and therefore every confidence-*ranked* trajectory, bitwise equal
    across backends whenever the vocab fits one kernel tile (K <=
    block_v; the multi-tile online accumulation is order-dependent).
    """
    a = adjust_logits(logits, mask=mask, temperature=temperature)
    sel = a if gumbel is None else a + gumbel
    tok = sel.argmax(-1).astype(jnp.int32)
    a_tok = jnp.take_along_axis(a, tok[..., None], axis=-1)[..., 0]
    m = a.max(-1)
    s = jnp.exp(a - m[..., None]).sum(-1)
    return tok, a_tok - (m + jnp.log(s))
