"""Pure-jnp oracle for the streaming (token, score) decode."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.dndm_update.ref import adjust_logits


def decode_scores_ref(logits, *, mask=None, temperature: float = 1.0,
                      gumbel=None):
    """logits: (B,N,K) -> (tokens (B,N) int32, scores (B,N) f32).

    Tokens are the argmax of the adjusted logits (+ Gumbel noise in
    sample mode) — the same selection ``dndm_update`` applies, so tokens
    agree bitwise with both ``fused_update`` and the streaming kernel.
    Scores are the log-softmax of the *noise-free* adjusted logits at the
    chosen token (the confidence the top-k samplers rank on).
    """
    a = adjust_logits(logits, mask=mask, temperature=temperature)
    sel = a if gumbel is None else a + gumbel
    tok = sel.argmax(-1).astype(jnp.int32)
    logp = jax.nn.log_softmax(a, axis=-1)
    score = jnp.take_along_axis(logp, tok[..., None], axis=-1)[..., 0]
    return tok, score
