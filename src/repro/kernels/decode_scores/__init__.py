"""Streaming (token, score) decode kernel package."""
from repro.kernels.decode_scores.ops import decode_scores  # noqa: F401
from repro.kernels.decode_scores.ref import decode_scores_ref  # noqa: F401
