"""jit'd wrapper for the streaming (token, score) decode.

Mirrors ``dndm_update.ops``: pads N and K up to TPU-friendly block
multiples (8-sublane / 128-lane granularity) instead of raising on
non-divisible shapes, and auto-detects the execution backend — compiled
Mosaic on TPU, the Pallas interpreter elsewhere (``interpret=None``, the
default).  Pass ``interpret`` explicitly to force either mode.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.decode_scores.kernel import decode_scores_kernel
from repro.kernels.dndm_update.ops import (_round_up, default_interpret,
                                           record_padding)


@partial(jax.jit, static_argnames=("temperature", "block_n", "block_v",
                                   "interpret"))
def decode_scores(logits, *, mask=None, gumbel=None,
                  temperature: float = 1.0, block_n: int = 256,
                  block_v: int = 1024, interpret: bool | None = None):
    """logits: (B,N,K); optional ``mask`` (K,) f32 additive logit penalty
    and ``gumbel`` (B,N,K) f32 noise (sample mode).  Returns
    (tokens (B,N) int32, scores (B,N) f32)."""
    if interpret is None:
        interpret = default_interpret()
    B, N, K = logits.shape
    bn = min(block_n, _round_up(N, 8))
    bkv = min(block_v, _round_up(K, 128))
    pad_n = _round_up(N, bn) - N
    pad_k = _round_up(K, bkv) - K
    record_padding("decode_scores", N, K, pad_n, pad_k)
    if mask is None:
        mask = jnp.zeros((K,), jnp.float32)
    mask = mask.astype(jnp.float32).reshape(1, K)
    if pad_n:
        logits = jnp.pad(logits, ((0, 0), (0, pad_n), (0, 0)))
        if gumbel is not None:
            gumbel = jnp.pad(gumbel, ((0, 0), (0, pad_n), (0, 0)))
    if pad_k:
        # -inf keeps padded vocab lanes out of the running max AND out of
        # the online logsumexp (exp(-inf) == 0); gumbel and mask pad with
        # 0 so the padded lanes stay at exactly -inf.
        logits = jnp.pad(logits, ((0, 0), (0, 0), (0, pad_k)),
                         constant_values=-jnp.inf)
        mask = jnp.pad(mask, ((0, 0), (0, pad_k)))
        if gumbel is not None:
            gumbel = jnp.pad(gumbel, ((0, 0), (0, 0), (0, pad_k)))
    tok, score = decode_scores_kernel(logits, mask, gumbel=gumbel,
                                      temperature=temperature, block_n=bn,
                                      block_v=bkv, interpret=interpret)
    return tok[:, :N], score[:, :N]
