"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships as a package: kernel.py (pl.pallas_call + BlockSpec),
ops.py (jit'd public wrapper) and ref.py (pure-jnp oracle used by the
allclose test sweeps).  On this CPU container kernels run with
interpret=True; on TPU the same call sites compile to Mosaic.

The decode layer owns two fused ops, both streaming over vocab tiles
without a (B, N, K) HBM intermediate:

  * ``dndm_update``   — select x0_hat + eq. (9) token update;
  * ``decode_scores`` — (token, score) pairs for the confidence-ranked
    samplers, with an online-logsumexp score head.
"""
