"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships as a package: kernel.py (pl.pallas_call + BlockSpec),
ops.py (jit'd public wrapper) and ref.py (pure-jnp oracle used by the
allclose test sweeps).  On this CPU container kernels run with
interpret=True; on TPU the same call sites compile to Mosaic.
"""
