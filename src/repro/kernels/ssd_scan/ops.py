"""jit'd wrapper: model layout (B,S,H,P) -> kernel layout, padding to
chunk multiples, slicing the result back."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_kernel


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dtv, A, Bm, Cm, *, chunk: int = 128,
             interpret: bool = True):
    """x: (B,S,H,P); dtv: (B,S,H); A: (H,); Bm/Cm: (B,S,N).
    Returns (y (B,S,H,P), None) matching the chunked-ref signature."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dtv = jnp.pad(dtv, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    C = (S + pad) // L
    xk = x.reshape(B, C, L, H, P).transpose(0, 3, 1, 2, 4)
    dtk = dtv.reshape(B, C, L, H).transpose(0, 3, 1, 2)
    Bk = Bm.reshape(B, C, L, N)
    Ck = Cm.reshape(B, C, L, N)
    y = ssd_scan_kernel(xk, dtk, A.astype(jnp.float32), Bk, Ck,
                        interpret=interpret)
    y = y.transpose(0, 2, 3, 1, 4).reshape(B, C * L, H, P)[:, :S]
    return y, None
