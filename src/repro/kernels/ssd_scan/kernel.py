"""Mamba-2 SSD chunked-scan Pallas kernel.

TPU adaptation of the SSD algorithm: instead of a GPU-style parallel
prefix scan over single steps, the sequence is processed in VMEM-resident
chunks of length L; each chunk does three small MXU matmuls
((L,N)x(N,L), (L,L)x(L,P), (L,N)x(N,P)) plus the rank-1 state update, and
the (N, P) running state is carried across the chunk grid dimension in
VMEM scratch — the sequential dependency is per-chunk, not per-step.

grid = (B, H, num_chunks), chunks innermost (sequential).
Inputs (rearranged by ops.py):
  x  : (B, H, C, L, P)   dt : (B, H, C, L)
  A  : (H,)  (negative)  Bm, Cm : (B, C, L, N)  (shared across heads)
Output: y : (B, H, C, L, P)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_scr):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0, 0].astype(jnp.float32)       # (L, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)     # (L,)
    a = a_ref[0].astype(jnp.float32)             # scalar
    bm = b_ref[0, 0].astype(jnp.float32)         # (L, N)
    cm = c_ref[0, 0].astype(jnp.float32)         # (L, N)
    L = x.shape[0]

    logdec = dt * a                              # (L,) <= 0
    cs = jnp.cumsum(logdec)
    gap = cs[:, None] - cs[None, :]              # decay(j -> i)
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    dec = jnp.where(tri, jnp.exp(gap), 0.0)
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())))   # (L, L)
    M = cb * dec * dt[None, :]
    y_intra = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())))

    state = state_scr[...]                       # (N, P)
    y_inter = jax.lax.dot_general(cm, state, (((1,), (0,)), ((), ()))) \
        * jnp.exp(cs)[:, None]
    y_ref[0, 0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: S <- exp(cs_L) S + sum_j exp(cs_L - cs_j) dt_j B_j x_j
    wj = jnp.exp(cs[-1] - cs) * dt               # (L,)
    sb = jax.lax.dot_general(bm * wj[:, None], x,
                             (((0,), (0,)), ((), ())))           # (N, P)
    state_scr[...] = jnp.exp(cs[-1]) * state + sb


def ssd_scan_kernel(x, dt, A, Bm, Cm, *, interpret: bool = True):
    """Shapes as in the module docstring.  Returns y (B,H,C,L,P)."""
    B, H, C, L, P = x.shape
    N = Bm.shape[-1]
    return pl.pallas_call(
        _ssd_kernel,
        grid=(B, H, C),
        in_specs=[
            pl.BlockSpec((1, 1, 1, L, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, L), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, L, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, L, N), lambda b, h, c: (b, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, L, P),
                               lambda b, h, c: (b, h, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, C, L, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
