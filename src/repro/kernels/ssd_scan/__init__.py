from repro.kernels.ssd_scan import ops, ref
from repro.kernels.ssd_scan.kernel import ssd_scan_kernel

__all__ = ["ops", "ref", "ssd_scan_kernel"]
