"""Pure-jnp oracles for the SSD scan kernel.

``ssd_scan_ref`` re-exports the chunked reference used by the Mamba-2
block; ``ssd_sequential_ref`` is the step-by-step recurrence — the ground
truth both the chunked form and the kernel must match.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.mamba2 import _ssd_scan_ref as ssd_chunked_ref  # noqa: F401


def ssd_sequential_ref(x, dtv, A, Bm, Cm):
    """x: (B,S,H,P); dtv: (B,S,H); A: (H,); Bm/Cm: (B,S,N).
    Exact per-step recurrence; returns (y, final_state)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]

    def step(state, inp):
        x_t, dt_t, b_t, c_t = inp
        dec = jnp.exp(dt_t * A)                       # (B,H)
        upd = jnp.einsum("bh,bn,bhp->bhnp", dt_t, b_t, x_t)
        state = state * dec[..., None, None] + upd
        y_t = jnp.einsum("bn,bhnp->bhp", c_t, state)
        return state, y_t

    s0 = jnp.zeros((B, H, N, P), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dtv.transpose(1, 0, 2).astype(jnp.float32),
          Bm.transpose(1, 0, 2).astype(jnp.float32),
          Cm.transpose(1, 0, 2).astype(jnp.float32))
    final, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), final
