import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""§Perf hillclimbing driver — hypothesis -> change -> measure -> validate.

Runs the three chosen (arch x shape) pairs through their iteration
ladders (single-pod mesh, per the brief: roofline table is single-pod).
Each iteration is one config/policy delta over the previous; results land
in results/perf/<pair>__<tag>.json and the before/after log is printed
for EXPERIMENTS.md §Perf.

    PYTHONPATH=src python -m repro.launch.perf [pair ...]
    pairs: mixtral_train | deepseek_prefill | xlstm_prefill
"""
import json
import sys
import time

from repro.launch.dryrun import run_one
from repro.launch.sharding import ShardingPolicy

OUT = "results/perf"

# Each entry: (pair_name, arch, shape, [(tag, hypothesis, overrides,
#                                        policy_kwargs), ...])
LADDERS = [
    (
        "mixtral_train", "mixtral-8x7b", "train_4k",
        [
            ("it1_local_dispatch",
             "GSPMD cannot shard the global sort-based MoE dispatch and "
             "replicates the (2.6M, 4096) expert buffers across the model "
             "axis (9 TB/chip all-reduce, useful_ratio 0.04). Dispatching "
             "within 16 data-aligned groups (vmap over a sharded leading "
             "dim) keeps every op shardable: expect collective term to "
             "drop >10x and useful_ratio toward ~0.5.",
             {"moe_dispatch": "local", "moe_local_groups": 16}, {}),
            ("it2_shard_map",
             "REFUTED it1 taught us GSPMD replicates the scatter across "
             "the *data* axis regardless (flops/chip == total/16, AG 4 "
             "TB). shard_map makes locality structural: per-shard "
             "dispatch, local (E,d,ff/16) expert matmuls, one explicit "
             "psum(model) per layer. Expect flops/chip -16x (useful "
             "0.04 -> ~0.5) and collective term -50x.",
             {"moe_dispatch": "shard_map"}, {}),
            ("it3_shard_map_blocked_attn",
             "With MoE fixed, the remaining memory term is the 4k x 4k "
             "SWA attention scores (B/chip=16, H=32). Blocked attention "
             "(flash-kernel model) removes the S^2 HBM traffic: expect "
             "memory term -30%+.",
             {"moe_dispatch": "shard_map",
              "attn_impl": "blocked", "attn_block_k": 1024}, {}),
            ("it4_microbatch4",
             "it2/it3 fixed time terms but the pair still does not FIT "
             "(725 GB/chip temp > 16 GB HBM). Gradient accumulation over "
             "4 unrolled microbatches keeps one microbatch of "
             "activations live: expect temp ~ /4 (+ params), roofline "
             "terms ~flat (same total bytes/flops). k=16 is the "
             "extrapolated production setting.",
             {"moe_dispatch": "shard_map", "microbatches": 4}, {}),
        ],
    ),
    (
        # bonus 4th pair (beyond the required three): the EP all-to-all
        "llama4_train", "llama4-maverick-400b-a17b", "train_4k",
        [
            ("it1_shard_map_ep",
             "llama4 has 128 experts (divisible by model=16), so the "
             "shard_map dispatch can run true expert parallelism: token "
             "slices travel to their experts via all-to-all (2 x buffer "
             "bytes/layer) instead of TP-psum. From the mixtral result "
             "expect collective 74 s -> ~3 s with the a2a signature, "
             "useful 0.065 -> ~0.5, and memory down ~5x.",
             {"moe_dispatch": "shard_map"}, {}),
        ],
    ),
    (
        "deepseek_prefill", "deepseek-7b", "prefill_32k",
        [
            ("it1_flash_attn",
             "The denoiser NFE pass (DNDM's unit of cost) is memory-bound "
             "on naive 32k^2 attention: scores are 2*32*32768^2*4B/chip "
             "read+written ~3x. The Pallas flash kernel keeps logits in "
             "VMEM (q,k,v,o HBM traffic only): expect memory term to "
             "drop ~5-10x and the pair to go compute-bound.",
             {"attn_impl": "blocked", "attn_block_k": 2048}, {}),
            ("it2_seq_parallel",
             "After flash, per-chip activations (B/chip=2, S=32k, d=4096) "
             "dominate bytes. Sharding the *sequence* dim of activations "
             "over the data axis (ring of 16) cuts per-chip activation "
             "traffic 16x at the cost of boundary collectives: expect "
             "memory term down, collective term up slightly.",
             {"attn_impl": "blocked", "attn_block_k": 2048},
             {"shard_seq_train": True}),
        ],
    ),
    (
        "xlstm_prefill", "xlstm-350m", "prefill_32k",
        [
            ("it1_chunked_mlstm",
             "mLSTM's parallel form materializes the (B, 32k, 32k, nh) "
             "decay matrix: useful_ratio 0.005, memory term 16s. The "
             "chunkwise form (L=2048, unrolled for costing) carries a "
             "(dh x dh) state across chunks: expect S^2 -> S*L, i.e. "
             "memory term -16x and hlo_flops -10x.",
             {"mlstm_chunk": 2048, "mlstm_unroll": True}, {}),
            ("it2_larger_chunks",
             "it1 cut memory 3.5x, not the predicted 16x: the surviving "
             "bytes are the chunked intra terms plus up/qkv projections. "
             "L=4096 halves the number of (dh x dh) state updates while "
             "doubling the intra-chunk quadratic: if memory stays ~flat "
             "the projections dominate and further chunk tuning is dead "
             "(<5% lever) — locates the new bottleneck.",
             {"mlstm_chunk": 4096, "mlstm_unroll": True}, {}),
            ("it3_seq_parallel",
             "With the quadratic gone, activations (B=32, S=32k, d=1k "
             "streams) should dominate like deepseek it2: shard the "
             "sequence dim over the data axis. Expect memory -2x.",
             {"mlstm_chunk": 4096, "mlstm_unroll": True},
             {"shard_seq_train": True}),
        ],
    ),
]


def main():
    only = sys.argv[1:]
    os.makedirs(OUT, exist_ok=True)
    for pair, arch, shape, ladder in LADDERS:
        if only and pair not in only:
            continue
        print(f"\n===== {pair}: {arch} x {shape} =====", flush=True)
        for tag, hypothesis, overrides, pol_kw in ladder:
            t0 = time.time()
            policy = ShardingPolicy(**pol_kw)
            rec = run_one(arch, shape, multi_pod=False, out_dir=OUT,
                          policy=policy, tag="__" + tag,
                          overrides=overrides)
            if rec["status"] == "ok":
                r = rec["roofline"]
                print(f"[{time.time()-t0:6.1f}s] {tag}: "
                      f"c={r['compute_s']:.3e} m={r['memory_s']:.3e} "
                      f"x={r['collective_s']:.3e} dom={r['dominant']} "
                      f"useful={r['useful_ratio']:.3f}", flush=True)
            else:
                print(f"[{time.time()-t0:6.1f}s] {tag}: ERROR "
                      f"{rec['error'][:200]}", flush=True)
            print(f"  hypothesis: {hypothesis}", flush=True)


if __name__ == "__main__":
    main()
