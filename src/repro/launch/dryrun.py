import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes, record memory / cost / collective analysis.

MUST be the process entry (python -m repro.launch.dryrun ...): the
XLA_FLAGS line above runs before any other import so the 512 placeholder
devices exist before jax locks the device count.

Per combination we lower the step the shape dictates:
  train_4k     -> train_step(state, batch, key)     (loss+grads+AdamW)
  prefill_32k  -> denoiser forward (one DNDM NFE)
  decode_*     -> serve_step(params, token, cache, pos)

Results land in results/dryrun/<arch>__<shape>__<mesh>.json and feed
EXPERIMENTS.md §Dry-run and §Roofline.
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.configs as configs_lib
from repro.configs.shapes import SHAPES
from repro.core import noise as noise_lib, schedules as sched_lib
from repro.launch import analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (ShardingPolicy, cache_spec, data_axes,
                                   shard_params_tree, tokens_spec)
from repro.models.frontend import frontend_spec
from repro.models.model import Model
from repro.training.optim import AdamW, constant
from repro.training.trainer import make_train_step


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def build_model(arch: str, shape_name: str, policy: ShardingPolicy,
                dtype: str = "bfloat16", remat: bool = True,
                overrides: dict | None = None) -> Model:
    cfg = configs_lib.get(arch)
    shp = SHAPES[shape_name]
    if shp.name == "long_500k":
        cfg = configs_lib.for_long_context(cfg)
    # unrolled layer stack => XLA cost analysis sees every layer
    cfg = cfg.replace(dtype=dtype, scan_layers=False,
                      remat=(remat and shp.kind == "train"),
                      bidirectional=(shp.kind != "decode"))
    if overrides:
        cfg = cfg.replace(**overrides)
    return Model(cfg)


def input_specs(model: Model, shape_name: str, mesh,
                policy: ShardingPolicy) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    cfg = model.cfg
    shp = SHAPES[shape_name]
    B, S = shp.global_batch, shp.seq_len
    da = data_axes(mesh)
    tok_spec = tokens_spec(mesh, B, policy,
                           seq_shard=(shp.kind in ("train", "prefill")))
    specs: dict = {}
    if shp.kind in ("train", "prefill"):
        specs["tokens"] = _sds((B, S), jnp.int32, mesh, tok_spec)
        specs["t"] = _sds((B,), jnp.float32, mesh, P(*tok_spec[:1]))
        if cfg.frontend:
            fs = frontend_spec(cfg, B)
            specs["frontend_embeds"] = _sds(
                fs.shape, fs.dtype, mesh, P(*tok_spec[:1], None, None))
    else:
        specs["token"] = _sds((B, 1), jnp.int32, mesh, tok_spec)
        specs["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
        cache_shapes = jax.eval_shape(
            lambda: model.init_cache(B, S, jnp.dtype(cfg.dtype)))
        def attach(path, leaf):
            last = str(getattr(path[-1], "key", path[-1]))
            kind = "kv" if last in ("k", "v") else "ssm"
            spec = cache_spec(mesh, leaf.shape, B, policy, kind)
            return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                        sharding=NamedSharding(mesh, spec))
        flat, td = jax.tree_util.tree_flatten_with_path(cache_shapes)
        specs["cache"] = jax.tree_util.tree_unflatten(
            td, [attach(kp, leaf) for kp, leaf in flat])
    return specs


def param_specs(model: Model, mesh, policy: ShardingPolicy):
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return shard_params_tree(shapes, mesh, policy, model.cfg)


def state_specs(model: Model, optimizer, mesh, policy: ShardingPolicy):
    params = param_specs(model, mesh, policy)
    opt = {"mu": params, "nu": params,
           "step": jax.ShapeDtypeStruct((), jnp.int32)}
    return {"params": params, "opt": opt,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def lower_one(arch: str, shape_name: str, mesh, policy: ShardingPolicy,
              remat: bool = True, overrides: dict | None = None):
    """Returns (lowered, compiled, model, wall_times)."""
    overrides = dict(overrides or {})
    microbatches = overrides.pop("microbatches", 1)   # trainer-level knob
    model = build_model(arch, shape_name, policy, remat=remat,
                        overrides=overrides)
    cfg = model.cfg
    shp = SHAPES[shape_name]
    specs = input_specs(model, shape_name, mesh, policy)
    t0 = time.time()

    # ambient mesh (jax.set_mesh) so shard_map-based blocks (MoE) can
    # resolve axis names without threading the mesh through the model
    with jax.set_mesh(mesh):
        if shp.kind == "train":
            sch = sched_lib.linear(50)
            nz = noise_lib.absorbing(cfg.vocab_size)
            opt = AdamW(schedule=constant(1e-4))
            step = make_train_step(model, sch, nz, opt,
                                   microbatches=microbatches)
            state = state_specs(model, opt, mesh, policy)
            batch = {"x0": specs["tokens"]}
            if cfg.frontend:
                batch["frontend_embeds"] = specs["frontend_embeds"]
            key = jax.random.PRNGKey(0)
            lowered = jax.jit(step).lower(state, batch, key)
        elif shp.kind == "prefill":
            params = param_specs(model, mesh, policy)

            def prefill(params, tokens, t, fe=None):
                logits, _ = model.forward(params, tokens, t, fe,
                                          causal=False)
                return logits

            args = [params, specs["tokens"], specs["t"]]
            if cfg.frontend:
                args.append(specs["frontend_embeds"])
            lowered = jax.jit(prefill).lower(*args)
        else:
            params = param_specs(model, mesh, policy)

            def serve_step(params, token, cache, pos):
                return model.decode_step(params, token, cache, pos)

            lowered = jax.jit(serve_step, donate_argnums=(2,)).lower(
                params, specs["token"], specs["cache"], specs["pos"])
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    return lowered, compiled, model, {"lower_s": t_lower,
                                      "compile_s": t_compile}


def analyse(arch: str, shape_name: str, mesh_name: str, compiled, model,
            walls: dict) -> dict:
    shp = SHAPES[shape_name]
    n_chips = 512 if mesh_name == "multi_pod" else 256
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    coll = analysis.collective_bytes(compiled.as_text())
    n_tokens = (shp.global_batch * shp.seq_len
                if shp.kind in ("train", "prefill") else shp.global_batch)
    mode = {"train": "train", "prefill": "prefill",
            "decode": "decode"}[shp.kind]
    mf = analysis.model_flops(model, n_tokens, mode)
    corr = analysis.corrections(model.cfg, shp.global_batch,
                                shp.seq_len, mode)
    terms = analysis.roofline(cost, coll, n_chips, mf, corr["flops"],
                              corr["bytes"])
    total, active = analysis.param_counts(model)
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "n_chips": n_chips,
        "params_total": total, "params_active": active,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "cost": {k: cost.get(k) for k in
                 ("flops", "bytes accessed") if k in cost},
        "collectives": coll,
        "roofline": {
            "compute_s": terms.compute_s,
            "memory_s": terms.memory_s,
            "collective_s": terms.collective_s,
            "dominant": terms.dominant,
            "model_flops": terms.model_flops,
            "hlo_flops_per_chip": terms.hlo_flops,
            "useful_ratio": terms.useful_ratio,
            "scan_correction_flops": terms.scan_correction_flops,
        },
        "walls": walls,
    }


def run_one(arch: str, shape_name: str, multi_pod: bool,
            out_dir: str, policy: ShardingPolicy | None = None,
            tag: str = "", overrides: dict | None = None) -> dict:
    mesh_name = "multi_pod" if multi_pod else "single_pod"
    out_path = os.path.join(
        out_dir, f"{arch}__{shape_name}__{mesh_name}{tag}.json")
    policy = policy or ShardingPolicy()
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        lowered, compiled, model, walls = lower_one(
            arch, shape_name, mesh, policy, overrides=overrides)
        rec = analyse(arch, shape_name, mesh_name, compiled, model, walls)
        rec["status"] = "ok"
        rec["tag"] = tag
        rec["overrides"] = overrides or {}
    except Exception as e:  # noqa: BLE001 — record failures, don't die
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
    os.makedirs(out_dir, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = (configs_lib.ASSIGNED_ARCHS if args.arch == "all"
             else [args.arch])
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                mesh_name = "multi_pod" if mp else "single_pod"
                path = os.path.join(
                    args.out, f"{arch}__{shape_name}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"skip {path}")
                    continue
                t0 = time.time()
                rec = run_one(arch, shape_name, mp, args.out)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" dom={r['dominant']}"
                             f" c={r['compute_s']:.2e}s"
                             f" m={r['memory_s']:.2e}s"
                             f" x={r['collective_s']:.2e}s")
                else:
                    extra = " " + rec["error"][:120]
                print(f"[{time.time()-t0:6.1f}s] {arch} x {shape_name} x "
                      f"{mesh_name}: {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
