"""Distributed training launcher.

On real hardware this builds the production mesh, shards params/optimizer
with the rule system and runs the pjit train step; on this CPU container
it runs the same code path over however many devices exist (use
launch/dryrun.py for the 512-device compile-only validation).

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --reduced --steps 20 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.configs as configs_lib
from repro.core import noise as noise_lib, schedules as sched_lib
from repro.data import DataConfig, DataPipeline
from repro.launch.sharding import ShardingPolicy, shard_params_tree, tokens_spec
from repro.models.model import Model
from repro.training import checkpoint
from repro.training.optim import AdamW, warmup_cosine
from repro.training.trainer import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dndm-text8")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--T", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--data-axis", type=int, default=0,
                    help="data-parallel size (0 = all devices)")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    n_dev = len(jax.devices())
    dp = args.data_axis or n_dev
    mesh = jax.make_mesh((dp, n_dev // dp), ("data", "model"))
    policy = ShardingPolicy()

    cfg = configs_lib.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = cfg.replace(bidirectional=True)
    model = Model(cfg)
    sch = sched_lib.linear(args.T)
    nz = noise_lib.absorbing(cfg.vocab_size)
    opt = AdamW(schedule=warmup_cosine(args.lr, 20, args.steps))

    key = jax.random.PRNGKey(0)
    state = init_state(model, opt, key)
    # shard the live state across the mesh
    state = {
        "params": shard_params_tree(state["params"], mesh, policy, cfg),
        "opt": {"mu": shard_params_tree(state["opt"]["mu"], mesh, policy,
                                        cfg),
                "nu": shard_params_tree(state["opt"]["nu"], mesh, policy,
                                        cfg),
                "step": state["opt"]["step"]},
        "step": state["step"],
    }
    step_fn = jax.jit(make_train_step(model, sch, nz, opt))

    pipe = DataPipeline(DataConfig(task="unconditional",
                                   vocab=cfg.vocab_size - 1,
                                   seq_len=args.seq, batch=args.batch))
    tok_sharding = NamedSharding(mesh, tokens_spec(mesh, args.batch,
                                                   policy))
    t0 = time.time()
    for i, batch in enumerate(pipe):
        if i >= args.steps:
            break
        key, k = jax.random.split(key)
        x0 = jax.device_put(jnp.asarray(batch["x0"]), tok_sharding)
        state, metrics = step_fn(state, {"x0": x0}, k)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(metrics['loss']):.4f} "
                  f"acc {float(metrics['masked_acc']):.3f} "
                  f"({time.time() - t0:.1f}s)", flush=True)
    if args.ckpt:
        checkpoint.save(args.ckpt, state["params"])
        print(f"saved params -> {args.ckpt}")


if __name__ == "__main__":
    main()
