"""Compiled-artifact analysis: collective-byte parsing, analytic FLOPs,
and the three roofline terms (compute / memory / collective).

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16 per chip, 819 GB/s
HBM per chip, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re

import numpy as np

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(segment):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes of every collective op in the HLO text.

    (Result bytes ~ data moved per chip for AR/AG; a documented proxy.)
    """
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+ = (.frontend_attributes=.*)?(.+?) "
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)(-start|-done)?\(", ls)
        if not m:
            continue
        op = m.group(3)
        if m.group(4) == "-done":
            continue                        # counted at -start
        out[op] += _shape_bytes(m.group(2))
        out["count"] += 1
    return out


@dataclasses.dataclass
class RooflineTerms:
    """All times in seconds (per chip, per step)."""

    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float                # per chip (from cost_analysis)
    hlo_bytes: float                # per chip
    coll_bytes: float               # per chip
    model_flops: float              # analytic, whole program
    scan_correction_flops: float    # sequential-scan flops invisible to HLO
    n_chips: int = 256

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / total compiled FLOPs (remat/redundancy waste)."""
        tot = self.hlo_flops * self.n_chips
        return self.model_flops / tot if tot else float("nan")


def roofline(cost: dict, coll: dict[str, int], n_chips: int,
             model_flops: float, scan_correction: float = 0.0,
             bytes_correction: float = 0.0,
             links_per_chip: float = 2.0) -> RooflineTerms:
    """cost: compiled.cost_analysis() dict (per-chip numbers on SPMD).

    collective bytes from the HLO are per-chip result shapes already.
    Corrections are whole-program and distributed evenly across chips.
    """
    flops = float(cost.get("flops", 0.0)) + scan_correction / n_chips
    bytes_ = max(0.0, float(cost.get("bytes accessed", 0.0)) +
                 bytes_correction / n_chips)
    cbytes = float(sum(v for k, v in coll.items() if k != "count"))
    return RooflineTerms(
        compute_s=flops / PEAK_FLOPS,
        memory_s=bytes_ / HBM_BW,
        collective_s=cbytes / (ICI_BW * links_per_chip),
        hlo_flops=flops, hlo_bytes=bytes_, coll_bytes=cbytes,
        model_flops=model_flops,
        scan_correction_flops=scan_correction,
        n_chips=n_chips,
    )


# ------------------------------------------------------------------
# Analytic model FLOPs
# ------------------------------------------------------------------

def param_counts(model) -> tuple[int, int]:
    """(total, active) parameter counts from eval_shape (no allocation)."""
    import jax
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    total = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    cfg = model.cfg
    active = total
    if cfg.n_experts:
        flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
        moe_params = 0
        for kp, leaf in flat:
            p = "/".join(str(getattr(k, "key", k)) for k in kp)
            if "/moe/" in p and not p.endswith("router"):
                moe_params += int(np.prod(leaf.shape))
        active = total - int(
            moe_params * (1 - cfg.experts_per_token / cfg.n_experts))
    return total, active


def model_flops(model, n_tokens: int, mode: str) -> float:
    """6*N_active*D for training, 2*N_active*D for inference passes."""
    _, active = param_counts(model)
    mult = 6.0 if mode == "train" else 2.0
    return mult * active * n_tokens


def scan_correction(cfg, batch: int, seq: int, mode: str) -> float:
    """FLOPs hidden inside sequential (time-axis) scans that XLA cost
    analysis counts only once: the sLSTM recurrent matmul.

    Per step per layer: (B, nh, dh) x (nh, dh, 4dh) = B*d*4dh MACs.
    """
    n_slstm = sum(1 for k in cfg.block_pattern if k == "slstm")
    if not n_slstm or mode == "decode":
        return 0.0
    nh = cfg.lstm_heads
    dh = cfg.d_model // nh
    per_step = 2.0 * batch * cfg.d_model * 4 * dh
    steps = seq * (2 if cfg.bidirectional else 1)
    fb = 3.0 if mode == "train" else 1.0       # fwd+bwd multiplier
    return n_slstm * per_step * steps * fb


def flash_attn_correction(cfg, batch: int, seq: int,
                          mode: str) -> tuple[float, float]:
    """(flops_corr, bytes_corr) when ``attn_impl == "blocked"``.

    The blocked (lax.scan) attention stands in for the Pallas flash
    kernel; XLA costs only one KV block.  We (a) add the missing blocks'
    FLOPs exactly, and (b) replace the counted block's HBM traffic with
    the fused kernel's model — Q, K, V read once and O written once per
    layer (the S^2 logits never leave VMEM on TPU).  bytes_corr can be
    negative.  Whole-program (all chips) numbers.
    """
    if cfg.attn_impl != "blocked" or mode == "decode":
        return 0.0, 0.0
    n_attn = sum(1 for k in cfg.block_pattern
                 if k in ("attn", "swa", "moe", "shared_attn"))
    if not n_attn:
        return 0.0, 0.0
    B, S, H, hd = batch, seq, cfg.n_heads, cfg.hd
    nk = max(1, -(-S // cfg.attn_block_k))
    dirs = 2 if cfg.bidirectional else 1
    fb = 3.0 if mode == "train" else 1.0
    dt_bytes = 2 if "16" in cfg.dtype else 4

    full = 4.0 * B * H * S * S * hd            # QK^T + PV (fwd, one dir)
    counted = full / nk
    flops_corr = (full - counted) * n_attn * dirs * fb

    flash_bytes = 4.0 * B * S * H * hd * dt_bytes          # q,k,v,o once
    # the counted block's dominant traffic: logits written + re-read by
    # softmax + probs read by PV: ~3 x (B,H,S,S/nk) fp32
    counted_bytes = 3.0 * B * H * S * (S / nk) * 4.0
    bytes_corr = (flash_bytes - counted_bytes) * n_attn * dirs * fb
    return flops_corr, bytes_corr


def corrections(cfg, batch: int, seq: int, mode: str) -> dict:
    """All analytic corrections for scan-hidden / kernel-fused compute."""
    f = scan_correction(cfg, batch, seq, mode)
    fa, ba = flash_attn_correction(cfg, batch, seq, mode)
    return {"flops": f + fa, "bytes": ba,
            "slstm_flops": f, "flash_flops": fa, "flash_bytes": ba}
