"""Declarative sharding rules: param-path / activation -> PartitionSpec.

``ShardingPolicy`` is the hillclimbing surface: every §Perf iteration
that changes a sharding scheme changes exactly one field here, so
baseline and optimized configurations are reproducible side by side.

All rules degrade gracefully: an axis is only applied when the dimension
is divisible by the axis size (``_ok``), otherwise that dim is
replicated — no config can fail to lower because of divisibility.
"""
from __future__ import annotations

import dataclasses
import re

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Baseline = classic Megatron-style TP + DP, expert-parallel MoE."""

    attn_tp: bool = True             # shard attention heads on "model"
    mlp_tp: bool = True              # shard d_ff on "model"
    moe_expert_parallel: bool = True  # experts on "model" when divisible
    ssm_tp: bool = False             # baseline: SSM/xLSTM blocks replicated
    embed_vocab_shard: bool = True   # embedding rows on "model"
    # activations
    shard_seq_train: bool = False    # sequence parallelism on "data"
    decode_cache_seq: str = "auto"   # "auto": shard cache seq on "data"
    #   when the batch is too small to fill the data axis; "always"/"never"
    logits_vocab_shard: bool = True


def _ok(dim: int, mesh, *axes: str) -> bool:
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return dim % size == 0 and size > 1


def _spec(mesh, shape, assignment: dict[int, tuple[str, ...]]) -> P:
    """Build a PartitionSpec, dropping non-divisible assignments."""
    entries = []
    for i, dim in enumerate(shape):
        axes = assignment.get(i)
        if axes and all(a in mesh.axis_names for a in axes) and \
                _ok(dim, mesh, *axes):
            entries.append(axes if len(axes) > 1 else axes[0])
        else:
            entries.append(None)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


# ------------------------------------------------------------------
# Parameter rules
# ------------------------------------------------------------------

_RULES: list[tuple[str, dict[int, tuple[str, ...]]]] = [
    # (regex on "/".join(path) WITHOUT the leading stack dim, rule)
    (r".*/attn/wq$", {1: ("model",)}),
    (r".*/attn/wk$", {1: ("model",)}),
    (r".*/attn/wv$", {1: ("model",)}),
    (r".*/attn/wo$", {0: ("model",)}),
    (r".*/mlp/(gate|up)$", {1: ("model",)}),
    (r".*/mlp/down$", {0: ("model",)}),
    (r".*/moe/(gate|up)$", {0: ("model",)}),      # expert-parallel
    (r".*/moe/down$", {0: ("model",)}),
    (r".*/moe/router$", {}),
    (r"^embed$", {0: ("model",)}),
    (r"^head$", {1: ("model",)}),
]

_SSM_TP_RULES: list[tuple[str, dict[int, tuple[str, ...]]]] = [
    (r".*/mixer/in_proj$", {1: ("model",)}),
    (r".*/mixer/out_proj$", {0: ("model",)}),
    (r".*/mixer/(up|wq|wk|wv)$", {1: ("model",)}),
    (r".*/mixer/down$", {0: ("model",)}),
]


def param_spec(path: str, shape: tuple[int, ...], mesh,
               policy: ShardingPolicy, cfg: ModelConfig) -> P:
    stacked = path.startswith("unit/")
    eff_shape = shape[1:] if stacked else shape

    rules = list(_RULES)
    if policy.ssm_tp:
        rules += _SSM_TP_RULES
    rule = None
    for pat, assignment in rules:
        if re.match(pat, path):
            rule = dict(assignment)
            break
    if rule is None:
        rule = {}

    # policy gates
    if not policy.attn_tp and "/attn/" in path:
        rule = {}
    if not policy.mlp_tp and "/mlp/" in path:
        rule = {}
    if "/moe/" in path and "router" not in path:
        if not (policy.moe_expert_parallel and
                _ok(cfg.n_experts, mesh, "model")):
            # fall back to tensor parallelism inside each expert
            if path.endswith("down"):
                rule = {1: ("model",)}       # (E, ff, d): shard ff
            else:
                rule = {2: ("model",)}       # (E, d, ff): shard ff
    if path == "embed" and not policy.embed_vocab_shard:
        rule = {}
    if path == "head" and not policy.logits_vocab_shard:
        rule = {}

    spec = _spec(mesh, eff_shape, rule)
    if stacked:
        spec = P(None, *spec)
    return spec


def shard_params_tree(shapes_tree, mesh, policy: ShardingPolicy,
                      cfg: ModelConfig):
    """Map a pytree of ShapeDtypeStruct (or arrays) -> same tree with
    NamedSharding attached (for arrays: device_put)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes_tree)
    out = []
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        spec = param_spec(path, leaf.shape, mesh, policy, cfg)
        sh = NamedSharding(mesh, spec)
        if isinstance(leaf, jax.ShapeDtypeStruct):
            out.append(jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                            sharding=sh))
        else:
            out.append(jax.device_put(leaf, sh))
    return jax.tree_util.tree_unflatten(treedef, out)


# ------------------------------------------------------------------
# Activation / input rules
# ------------------------------------------------------------------

def data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def tokens_spec(mesh, batch: int, policy: ShardingPolicy,
                seq_shard: bool = False) -> P:
    da = data_axes(mesh)
    baxes = da if _ok(batch, mesh, *da) else ()
    b = baxes if baxes else None
    if seq_shard and policy.shard_seq_train:
        return P(b, "model")
    return P(b, None)


def cache_spec(mesh, shape: tuple[int, ...], batch: int,
               policy: ShardingPolicy, kind: str) -> P:
    """KV cache (B, L, KV, hd) or SSM state (B, ...), with leading stack
    dim.  Context parallelism: shard L on the data axes when the batch is
    too small to occupy them."""
    da = data_axes(mesh)
    b_ok = _ok(batch, mesh, *da)
    if kind == "kv":                          # (stack, B, L, KV, hd)
        rule: dict[int, tuple[str, ...]] = {}
        if b_ok:
            rule[1] = da
            seq_on_data = policy.decode_cache_seq == "always"
        else:
            seq_on_data = policy.decode_cache_seq in ("auto", "always")
        if seq_on_data:
            rule[2] = da if not b_ok else ()
        rule[3] = ("model",)                  # kv heads if divisible
        return _spec(mesh, shape, {k: v for k, v in rule.items() if v})
    # ssm state: (stack, B, ...) — batch on data, rest replicated/model
    rule = {1: da} if b_ok else {}
    return _spec(mesh, shape, rule)
