"""Production meshes.

Single pod: (16, 16) = ("data", "model") — 256 chips (TPU v5e pod).
Multi-pod:  (2, 16, 16) = ("pod", "data", "model") — 512 chips.

``make_production_mesh`` is a function (not a module constant) so that
importing this module never touches jax device state; callers must have
set ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before*
jax's first initialization (dryrun.py does this in its first two lines).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """Axes used for data parallelism: ("pod","data") or ("data",)."""
    return tuple(a for a in mesh.axis_names if a != "model")


def axis_size(mesh: jax.sharding.Mesh, *names: str) -> int:
    s = 1
    for n in names:
        s *= mesh.shape[n]
    return s
