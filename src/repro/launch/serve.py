"""Serving launcher: load a checkpoint (or init), build the generation
engine on the local mesh, drain a batch of synthetic requests.

    PYTHONPATH=src python -m repro.launch.serve --arch dndm-text8 \
        --reduced --requests 16 --method dndm_topk_static
"""
from __future__ import annotations

import argparse
import time

import jax

import repro.configs as configs_lib
from repro.core.samplers import registry
from repro.models.model import Model
from repro.serving import BatchScheduler, EngineConfig, GenerationEngine
from repro.training import checkpoint


def main():
    ap = argparse.ArgumentParser(
        formatter_class=argparse.RawDescriptionHelpFormatter,
        epilog="registered samplers:\n" + registry.describe())
    ap.add_argument("--arch", default="dndm-text8")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--method", default="dndm_topk_static",
                    choices=registry.names(),
                    help="sampler (from the registry)")
    ap.add_argument("--noise-kind", default="absorbing",
                    choices=("absorbing", "multinomial"))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--nfe-budget", type=int, default=16)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--len", type=int, default=64)
    args = ap.parse_args()

    cfg = configs_lib.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = cfg.replace(bidirectional=True)
    model = Model(cfg)
    if args.ckpt:
        import jax.numpy as jnp
        params = jax.tree.map(jnp.asarray, checkpoint.load(args.ckpt))
    else:
        params = model.init(jax.random.PRNGKey(0))

    engine = GenerationEngine(model, params, EngineConfig(
        method=args.method, steps=args.steps, nfe_budget=args.nfe_budget,
        noise_kind=args.noise_kind))
    sched = BatchScheduler(engine, max_batch=args.max_batch,
                           bucket_len=args.len)
    t0 = time.time()
    for _ in range(args.requests):
        sched.submit(args.len)
    done = sched.run()
    wall = time.time() - t0
    nfe = sum(r.nfe for r in done.values())
    print(f"{len(done)} requests in {wall:.2f}s "
          f"({len(done) / wall:.2f} req/s), total NFE {nfe}")


if __name__ == "__main__":
    main()
