"""starcoder2-3b [dense] — GQA + RoPE code model [arXiv:2402.19173].
30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152.  GeLU MLP.
"""
from repro.models.config import ModelConfig, dense_pattern


def get_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b", arch_type="dense",
        n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
        d_ff=12288, vocab_size=49152,
        block_pattern=dense_pattern(30),
        mlp_type="gelu", rope_theta=1e5,
        paper="arXiv:2402.19173",
    )
