"""tinyllama-1.1b [dense] — llama2-architecture small [arXiv:2401.02385].
22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000.
"""
from repro.models.config import ModelConfig, dense_pattern


def get_config() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b", arch_type="dense",
        n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4,
        d_ff=5632, vocab_size=32000,
        block_pattern=dense_pattern(22),
        paper="arXiv:2401.02385",
    )
