"""phi3-mini-3.8b [dense] — RoPE + SwiGLU + GQA [arXiv:2404.14219].
32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064.
"""
from repro.models.config import ModelConfig, dense_pattern


def get_config() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b", arch_type="dense",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=32064,
        block_pattern=dense_pattern(32),
        paper="arXiv:2404.14219",
    )
