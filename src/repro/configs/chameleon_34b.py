"""chameleon-34b [vlm] — early-fusion mixed-modal transformer over text +
VQ image tokens [arXiv:2405.09818].  48L d_model=8192 64H (GQA kv=8)
d_ff=22016 vocab=65536.  The VQ image encoder is the allowed frontend
STUB: input_specs() supplies precomputed patch embeddings fused into the
first ``frontend_tokens`` positions.
"""
from repro.models.config import ModelConfig, dense_pattern


def get_config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b", arch_type="vlm",
        n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=22016, vocab_size=65536,
        block_pattern=dense_pattern(48),
        frontend="vision", frontend_tokens=512,
        paper="arXiv:2405.09818",
    )
