"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242].  54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64.  Unit: 5 Mamba2 layers + 1 shared-weight
attention block (the Zamba trick: one global attention parameter set
reused at every application site), repeated 9x = 54 layers.
"""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    unit = ("mamba2",) * 5 + ("shared_attn",)
    return ModelConfig(
        name="zamba2-2.7b", arch_type="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=10240, vocab_size=32000,
        block_pattern=unit * 9,
        ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssd_chunk=128,
        paper="arXiv:2411.15242",
    )
