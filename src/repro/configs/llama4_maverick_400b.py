"""llama4-maverick-400b-a17b [moe] — 128 experts top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].  48L d_model=5120 40H (GQA kv=8)
expert d_ff=8192 vocab=202048.
"""
from repro.models.config import ModelConfig, moe_pattern


def get_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b", arch_type="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=8192, vocab_size=202048,
        block_pattern=moe_pattern(48),
        n_experts=128, experts_per_token=1,
        rope_theta=5e5,
        paper="hf:meta-llama/Llama-4-Scout-17B-16E",
    )
