"""The paper's conditional MT setup adapted to the decoder-only early-
fusion form: source prefix + target canvas, bidirectional attention
(paper §4.1 uses a FairSeq encoder-decoder; our framework realizes the
same q(x0 | x_t, z) with prefix conditioning).
"""
from repro.models.config import ModelConfig, dense_pattern


def get_config() -> ModelConfig:
    return ModelConfig(
        name="dndm-mt", arch_type="dense",
        n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
        d_ff=2048, vocab_size=28,
        block_pattern=dense_pattern(6),
        bidirectional=True,
        paper="DNDM paper §4.1 (RDM/FairSeq-scale transformer)",
    )
