"""musicgen-large [audio] — decoder-only transformer over EnCodec tokens
[arXiv:2306.05284].  48L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=2048 (EnCodec codebook).  The EnCodec conv codec is the allowed
frontend STUB: input_specs() provides precomputed conditioning frame
embeddings occupying the first ``frontend_tokens`` positions.
"""
from repro.models.config import ModelConfig, dense_pattern


def get_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large", arch_type="audio",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=2048,
        block_pattern=dense_pattern(48),
        mlp_type="gelu",
        frontend="audio", frontend_tokens=128,
        paper="arXiv:2306.05284",
    )
