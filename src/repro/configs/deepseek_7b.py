"""deepseek-7b [dense] — llama-architecture [arXiv:2401.02954].
30L d_model=4096 32H (GQA kv=32) d_ff=11008 vocab=102400.
"""
from repro.models.config import ModelConfig, dense_pattern


def get_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b", arch_type="dense",
        n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32,
        d_ff=11008, vocab_size=102400,
        block_pattern=dense_pattern(30),
        paper="arXiv:2401.02954",
    )
