"""The four assigned input shapes.

train_4k / prefill_32k lower full-sequence programs (train_step /
denoiser-NFE forward); decode_32k / long_500k lower ``serve_step`` —
one new token against a KV/state cache of ``seq_len``.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # "train" | "prefill" | "decode"


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def get(name: str) -> InputShape:
    return SHAPES[name]
