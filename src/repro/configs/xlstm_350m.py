"""xlstm-350m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].
24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304.  xLSTM blocks carry
their own up/down projections, so d_ff=0 (no separate FFN).  Ratio ~5:1
mLSTM:sLSTM (the paper's large models are mLSTM-dominant).
"""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    unit = ("mlstm",) * 5 + ("slstm",)
    return ModelConfig(
        name="xlstm-350m", arch_type="ssm",
        n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=50304,
        block_pattern=unit * 4,
        lstm_heads=4,
        paper="arXiv:2405.04517",
    )
