"""The paper's own unconditional model: 12-layer decoder-only Transformer
for text8-style character diffusion (paper §4.2), 27 chars + [MASK].
"""
from repro.models.config import ModelConfig, dense_pattern


def get_config() -> ModelConfig:
    return ModelConfig(
        name="dndm-text8", arch_type="dense",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=3072, vocab_size=28,
        block_pattern=dense_pattern(12),
        bidirectional=True,
        paper="DNDM paper §4.2 (Hoogeboom-style 12L Transformer)",
    )
