"""Config registry: ``--arch <id>`` ids -> ModelConfig factories.

The ten assigned architectures (public-literature pool) plus the paper's
own models.  ``for_long_context`` swaps full attention for sliding-window
attention — the documented substitute that makes ``long_500k`` lowerable
for otherwise-quadratic architectures (see DESIGN.md §2).
"""
from __future__ import annotations

from repro.configs import shapes
from repro.configs.shapes import SHAPES, InputShape
from repro.models.config import ModelConfig

_ARCH_MODULES = {
    "zamba2-2.7b": "zamba2_2p7b",
    "xlstm-350m": "xlstm_350m",
    "mixtral-8x7b": "mixtral_8x7b",
    "musicgen-large": "musicgen_large",
    "starcoder2-3b": "starcoder2_3b",
    "phi3-mini-3.8b": "phi3_mini_3p8b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
    "deepseek-7b": "deepseek_7b",
    "chameleon-34b": "chameleon_34b",
    "tinyllama-1.1b": "tinyllama_1p1b",
    "dndm-text8": "dndm_text8",
    "dndm-mt": "dndm_mt",
}

ASSIGNED_ARCHS = tuple(list(_ARCH_MODULES)[:10])


def get(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_ARCH_MODULES)}")
    import importlib
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.get_config()


def list_archs() -> list[str]:
    return list(_ARCH_MODULES)


LONG_CONTEXT_WINDOW = 4096


def for_long_context(cfg: ModelConfig) -> ModelConfig:
    """Sub-quadratic variant for long_500k decode.

    SSM / hybrid / SWA architectures are already sub-quadratic; pure
    full-attention blocks are swapped for sliding-window ("swa") blocks
    with a 4k window (the documented dense-arch substitute).
    """
    pattern = tuple("swa" if k == "attn" else k for k in cfg.block_pattern)
    window = cfg.sliding_window or LONG_CONTEXT_WINDOW
    # shared_attn occurrences also become windowed via cfg.sliding_window?
    # Zamba's shared attention keeps full span: its cache is seq-sharded.
    return cfg.replace(block_pattern=pattern, sliding_window=window)


__all__ = ["get", "list_archs", "ASSIGNED_ARCHS", "SHAPES", "InputShape",
           "shapes", "for_long_context", "LONG_CONTEXT_WINDOW"]
