"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention
[arXiv:2401.04088].  32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000.
"""
from repro.models.config import ModelConfig, moe_pattern


def get_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", arch_type="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=32000,
        block_pattern=moe_pattern(32),
        n_experts=8, experts_per_token=2,
        sliding_window=4096, rope_theta=1e6,
        paper="arXiv:2401.04088",
    )
