"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM
(scalar memory, sequential recurrence).  Follows Beck et al. 2024
(arXiv:2405.04517) with exponential gating and max-stabilizers.

mLSTM trains in the attention-like parallel form (one S^2 pass with the
cumulative-forget decay matrix) and decodes with the exact (dh x dh)
matrix-memory recurrence.  sLSTM is a genuine per-step recurrence
(lax.scan over time) with block-diagonal recurrent weights per head.

NOTE (roofline): the sLSTM time scan is sequential; XLA cost analysis
counts its body once, so dry-run FLOPs for sLSTM layers are corrected
analytically (see EXPERIMENTS §Roofline).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, rmsnorm, rmsnorm_init

Array = jnp.ndarray


# ====================== mLSTM ======================

def mlstm_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_in = 2 * d
    nh = cfg.lstm_heads
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.dtype)
    return {
        "up": dense_init(ks[0], d, 2 * d_in, dt),      # [x_m, z]
        "wq": dense_init(ks[1], d_in, d_in, dt),
        "wk": dense_init(ks[2], d_in, d_in, dt),
        "wv": dense_init(ks[3], d_in, d_in, dt),
        "wif": dense_init(ks[4], d_in, 2 * nh, dt, scale=0.1),
        "norm": rmsnorm_init(d_in, dt),
        "down": dense_init(ks[5], d_in, d, dt),
    }


def _mlstm_parallel(q, k, v, logi, logf):
    """q,k,v: (B,S,nh,dh); logi/logf: (B,S,nh).  Stabilized parallel form."""
    B, S, nh, dh = q.shape
    cf = jnp.cumsum(logf, axis=1)                        # (B,S,nh)
    # D_ij = cf_i - cf_j + logi_j  for j <= i
    Dm = (cf[:, :, None, :] - cf[:, None, :, :] +
          logi[:, None, :, :])                            # (B,Si,Sj,nh)
    tri = jnp.tril(jnp.ones((S, S), bool))
    Dm = jnp.where(tri[None, :, :, None], Dm, -jnp.inf)
    m = Dm.max(axis=2)                                   # (B,Si,nh)
    dmat = jnp.exp(Dm - m[:, :, None, :])
    qk = jnp.einsum("bihd,bjhd->bijh", q, k) / (dh ** 0.5)
    w = qk * dmat
    denom = jnp.maximum(jnp.abs(w.sum(2)), jnp.exp(-m))  # (B,Si,nh)
    h = jnp.einsum("bijh,bjhd->bihd", w, v) / denom[..., None]
    return h


def _mlstm_chunked(q, k, v, logi, logf, chunk: int, unroll: bool):
    """Chunkwise-parallel mLSTM: within-chunk quadratic D-matrix,
    cross-chunk (C, n, m) matrix-memory carry.  O(S*L) memory instead of
    O(S^2) — the §Perf fix for the mLSTM prefill memory wall; exactly
    equal (up to fp) to the full parallel form.
    """
    B, S, nh, dh = q.shape
    L = min(chunk, S)
    nc = -(-S // L)
    pad = nc * L - S
    if pad:
        z4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(a, z4) for a in (q, k, v))
        logi = jnp.pad(logi, ((0, 0), (0, pad), (0, 0)),
                       constant_values=-1e9)    # pad tokens: no input
        logf = jnp.pad(logf, ((0, 0), (0, pad), (0, 0)))
    def cshape(a):
        return a.reshape(B, nc, L, *a.shape[2:]).swapaxes(0, 1)
    qc, kc, vc, ic, fc = map(cshape, (q, k, v, logi, logf))
    scale = 1.0 / (dh ** 0.5)

    def body(carry, inp):
        C_prev, n_prev, m_prev = carry          # (B,nh,dh,dh),(B,nh,dh),(B,nh)
        qq, kk, vv, li, lf = inp                # (B,L,...)
        b = jnp.cumsum(lf, axis=1)              # (B,L,nh) inclusive
        # intra-chunk D matrix
        Dm = b[:, :, None, :] - b[:, None, :, :] + li[:, None, :, :]
        tri = jnp.tril(jnp.ones((Dm.shape[1], Dm.shape[1]), bool))
        Dm = jnp.where(tri[None, :, :, None], Dm, -jnp.inf)
        m_loc = Dm.max(axis=2)                  # (B,Li,nh)
        a_inter = b + m_prev[:, None, :]        # log-scale of prev state
        m_i = jnp.maximum(m_loc, a_inter)
        w = (jnp.einsum("bihd,bjhd->bijh", qq, kk) * scale *
             jnp.exp(Dm - m_i[:, :, None, :]))
        scale_prev = jnp.exp(a_inter - m_i)     # (B,Li,nh)
        num = (jnp.einsum("bijh,bjhd->bihd", w, vv) +
               jnp.einsum("bihd,bhde,bih->bihe", qq * scale,
                          C_prev, scale_prev))
        den_loc = w.sum(2)
        den_prev = jnp.einsum("bihd,bhd->bih", qq * scale,
                              n_prev) * scale_prev
        den = jnp.maximum(jnp.abs(den_loc + den_prev), jnp.exp(-m_i))
        h = num / den[..., None]

        # carry update
        g = b[:, -1]                            # (B,nh) total log-decay
        m_kv = (g[:, None, :] - b + li).max(axis=1)      # (B,nh)
        m_new = jnp.maximum(g + m_prev, m_kv)
        wj = jnp.exp(g[:, None, :] - b + li - m_new[:, None, :])
        C_new = (jnp.exp(g + m_prev - m_new)[..., None, None] * C_prev +
                 jnp.einsum("bjh,bjhd,bjhe->bhde", wj, kk, vv))
        n_new = (jnp.exp(g + m_prev - m_new)[..., None] * n_prev +
                 jnp.einsum("bjh,bjhd->bhd", wj, kk))
        return (C_new, n_new, m_new), h

    C0 = jnp.zeros((B, nh, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, nh, dh), jnp.float32)
    m0 = jnp.full((B, nh), -1e9, jnp.float32)
    if unroll:
        carry = (C0, n0, m0)
        hs = []
        for c in range(nc):
            carry, h = body(carry, (qc[c], kc[c], vc[c], ic[c], fc[c]))
            hs.append(h)
        h = jnp.stack(hs, 0)
    else:
        _, h = jax.lax.scan(body, (C0, n0, m0), (qc, kc, vc, ic, fc))
    h = h.swapaxes(0, 1).reshape(B, nc * L, nh, dh)[:, :S]
    return h


def mlstm_apply(params: dict, u: Array, cfg: ModelConfig, *,
                bidirectional: bool = False) -> Array:
    B, S, d = u.shape
    d_in = 2 * d
    nh = cfg.lstm_heads
    dh = d_in // nh

    def one(u):
        xu = u @ params["up"]
        x_m, z = jnp.split(xu, 2, axis=-1)
        q = (x_m @ params["wq"]).reshape(B, S, nh, dh)
        k = (x_m @ params["wk"]).reshape(B, S, nh, dh)
        v = (x_m @ params["wv"]).reshape(B, S, nh, dh)
        gif = (x_m @ params["wif"]).astype(jnp.float32)
        logi, f_raw = jnp.split(gif.reshape(B, S, 2, nh), 2, axis=2)
        logi = logi[:, :, 0]
        logf = -jax.nn.softplus(-f_raw[:, :, 0])          # log sigmoid
        qf, kf, vf = (a.astype(jnp.float32) for a in (q, k, v))
        if cfg.mlstm_chunk and cfg.mlstm_chunk < S:
            h = _mlstm_chunked(qf, kf, vf, logi, logf, cfg.mlstm_chunk,
                               cfg.mlstm_unroll)
        else:
            h = _mlstm_parallel(qf, kf, vf, logi, logf)
        h = h.reshape(B, S, d_in).astype(u.dtype)
        h = rmsnorm(params["norm"], h, cfg.norm_eps) * jax.nn.silu(z)
        return h @ params["down"]

    y = one(u)
    if bidirectional:
        y = y + jnp.flip(one(jnp.flip(u, axis=1)), axis=1)
    return y


def mlstm_init_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    d_in = 2 * cfg.d_model
    nh = cfg.lstm_heads
    dh = d_in // nh
    return {
        "C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, nh, dh), jnp.float32),
        "m": jnp.full((batch, nh), -1e9, jnp.float32),
    }


def mlstm_decode(params: dict, u: Array, cache: dict,
                 cfg: ModelConfig) -> tuple[Array, dict]:
    """u: (B,1,d).  Exact matrix-memory recurrence."""
    B, _, d = u.shape
    d_in = 2 * d
    nh = cfg.lstm_heads
    dh = d_in // nh
    xu = u[:, 0] @ params["up"]
    x_m, z = jnp.split(xu, 2, axis=-1)
    q = (x_m @ params["wq"]).reshape(B, nh, dh).astype(jnp.float32)
    k = (x_m @ params["wk"]).reshape(B, nh, dh).astype(jnp.float32)
    v = (x_m @ params["wv"]).reshape(B, nh, dh).astype(jnp.float32)
    gif = (x_m @ params["wif"]).astype(jnp.float32).reshape(B, 2, nh)
    logi, logf = gif[:, 0], -jax.nn.softplus(-gif[:, 1])
    m_new = jnp.maximum(logf + cache["m"], logi)
    a = jnp.exp(logf + cache["m"] - m_new)[..., None]
    b = jnp.exp(logi - m_new)[..., None]
    C = cache["C"] * a[..., None] + b[..., None] * (
        k[..., :, None] * v[..., None, :])
    n = cache["n"] * a + b * k
    num = jnp.einsum("bhd,bhde->bhe", q / (dh ** 0.5), C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh",
                                         q / (dh ** 0.5), n)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(B, d_in).astype(u.dtype)
    h = rmsnorm(params["norm"], h, cfg.norm_eps) * jax.nn.silu(z)
    y = (h @ params["down"])[:, None]
    return y, {"C": C, "n": n, "m": m_new}


# ====================== sLSTM ======================

def slstm_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    nh = cfg.lstm_heads
    dh = d // nh
    ks = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.dtype)
    return {
        "w": dense_init(ks[0], d, 4 * d, dt),            # i,f,z,o
        "r": (jax.random.truncated_normal(ks[1], -2, 2, (nh, dh, 4 * dh)) *
              (1.0 / dh ** 0.5)).astype(dt),
        "norm": rmsnorm_init(d, dt),
        "down": dense_init(ks[2], d, d, dt),
    }


def _slstm_cell(params, x_t, state, cfg: ModelConfig):
    """x_t: (B, 4*d) pre-activations from inputs; state dict."""
    nh = cfg.lstm_heads
    d = x_t.shape[-1] // 4
    dh = d // nh
    h_prev = state["h"]                                   # (B,nh,dh)
    rec = jnp.einsum("bhd,hde->bhe", h_prev,
                     params["r"].astype(jnp.float32))     # (B,nh,4*dh)
    raw = x_t.reshape(-1, nh, 4 * dh).astype(jnp.float32) + rec
    i_r, f_r, z_r, o_r = jnp.split(raw, 4, axis=-1)
    logi, logf = i_r, -jax.nn.softplus(-f_r)
    m_new = jnp.maximum(logf + state["m"], logi)
    a, b = jnp.exp(logf + state["m"] - m_new), jnp.exp(logi - m_new)
    c = a * state["c"] + b * jnp.tanh(z_r)
    n = a * state["n"] + b
    h = jax.nn.sigmoid(o_r) * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "m": m_new, "h": h}


def slstm_apply(params: dict, u: Array, cfg: ModelConfig, *,
                bidirectional: bool = False) -> Array:
    B, S, d = u.shape
    nh = cfg.lstm_heads
    dh = d // nh

    def one(u):
        pre = u @ params["w"]                             # (B,S,4d)
        state = {"c": jnp.zeros((B, nh, dh), jnp.float32),
                 "n": jnp.zeros((B, nh, dh), jnp.float32),
                 "m": jnp.full((B, nh, dh), -1e9, jnp.float32),
                 "h": jnp.zeros((B, nh, dh), jnp.float32)}

        def step(state, x_t):
            new = _slstm_cell(params, x_t, state, cfg)
            return new, new["h"]

        _, hs = jax.lax.scan(step, state, pre.transpose(1, 0, 2))
        h = hs.transpose(1, 0, 2, 3).reshape(B, S, d).astype(u.dtype)
        h = rmsnorm(params["norm"], h, cfg.norm_eps)
        return h @ params["down"]

    y = one(u)
    if bidirectional:
        y = y + jnp.flip(one(jnp.flip(u, axis=1)), axis=1)
    return y


def slstm_init_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    nh = cfg.lstm_heads
    dh = cfg.d_model // nh
    z = jnp.zeros((batch, nh, dh), jnp.float32)
    return {"c": z, "n": z, "m": jnp.full((batch, nh, dh), -1e9,
                                          jnp.float32), "h": z}


def slstm_decode(params: dict, u: Array, cache: dict,
                 cfg: ModelConfig) -> tuple[Array, dict]:
    B, _, d = u.shape
    pre = (u[:, 0] @ params["w"])
    new = _slstm_cell(params, pre, cache, cfg)
    h = new["h"].reshape(B, d).astype(u.dtype)
    h = rmsnorm(params["norm"], h, cfg.norm_eps)
    return (h @ params["down"])[:, None], new
