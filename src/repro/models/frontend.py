"""Modality frontend *stubs* (the one allowed stub, per the brief).

For [audio] (MusicGen) and [vlm] (Chameleon) we implement the language /
decoder transformer only.  The conv codec (EnCodec) and the vision encoder
(VQ tokenizer) are represented by precomputed embeddings of the correct
shape, produced here (random projections of a seeded key at test time,
``ShapeDtypeStruct`` placeholders in the dry-run).

``frontend_embeds`` occupy the first ``cfg.frontend_tokens`` positions of
the sequence (early fusion): the model overwrites its token embeddings at
those positions with the provided vectors.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def fake_frontend_embeds(key, cfg: ModelConfig, batch: int):
    """Stand-in for EnCodec frames / ViT patch embeddings."""
    if not cfg.frontend:
        return None
    return jax.random.normal(
        key, (batch, cfg.frontend_tokens, cfg.d_model),
        jnp.dtype(cfg.dtype)) * 0.02


def frontend_spec(cfg: ModelConfig, batch: int, sharding=None):
    """ShapeDtypeStruct for the dry-run input_specs()."""
    if not cfg.frontend:
        return None
    return jax.ShapeDtypeStruct(
        (batch, cfg.frontend_tokens, cfg.d_model), jnp.dtype(cfg.dtype),
        sharding=sharding)


def fuse(h, frontend_embeds):
    """Early fusion: overwrite the first F positions."""
    if frontend_embeds is None:
        return h
    F = frontend_embeds.shape[1]
    return jnp.concatenate([frontend_embeds.astype(h.dtype), h[:, F:]],
                           axis=1)
