"""Mamba-2 block (SSD — state-space duality), TPU-shaped.

Forward training pass uses the chunked SSD algorithm: the sequence is cut
into chunks of length ``ssd_chunk``; within a chunk the recurrence is the
MXU-friendly quadratic form, across chunks a cheap sequential scan carries
the (H, N, P) state.  This is the pure-JAX oracle mirrored by
``kernels/ssd_scan``.

Decode is the exact single-step recurrence with a (H, N, P) state and a
depthwise-conv ring buffer — O(1) per token, which is what makes
``long_500k`` feasible for SSM/hybrid architectures.

Weight layout (groups = 1):
  in_proj : d -> [z (d_in), x (d_in), B (N), C (N), dt (H)]
  conv    : depthwise width-w over the [x, B, C] channels
  A_log, D, dt_bias : (H,)
  out_proj: d_in -> d
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, rmsnorm, rmsnorm_init

Array = jnp.ndarray


def init(key, cfg: ModelConfig) -> dict:
    d, d_in, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    w = cfg.conv_width
    conv_ch = d_in + 2 * N
    ks = jax.random.split(key, 5)
    dt = jnp.dtype(cfg.dtype)
    return {
        "in_proj": dense_init(ks[0], d, 2 * d_in + 2 * N + H, dt),
        "conv_w": (jax.random.truncated_normal(ks[1], -2, 2, (w, conv_ch)) *
                   (1.0 / w ** 0.5)).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dt),
        "D": jnp.ones((H,), dt),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01))).astype(dt),
        "norm": rmsnorm_init(d_in, dt),
        "out_proj": dense_init(ks[2], d_in, d, dt),
    }


def _split(params, u, cfg: ModelConfig):
    d_in, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = u @ params["in_proj"]
    z, xBC, dt_raw = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * N], axis=-1)
    return z, xBC, dt_raw


def _post(params, y, z, cfg: ModelConfig):
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ params["out_proj"]


def _causal_conv(xBC: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv: xBC (B,S,C), w (W,C)."""
    W = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1]] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


def _ssd_scan_ref(x, dtv, A, Bm, Cm, chunk: int):
    """Chunked SSD.  x: (B,S,H,P); dtv: (B,S,H); A: (H,) negative;
    Bm, Cm: (B,S,N).  Returns y (B,S,H,P) and final state (B,H,N,P)."""
    Bb, S, H, P = x.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    nc = -(-S // L)
    pad = nc * L - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dtv = jnp.pad(dtv, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    xc = x.reshape(Bb, nc, L, H, P)
    dtc = dtv.reshape(Bb, nc, L, H)
    Bc = Bm.reshape(Bb, nc, L, N)
    Cc = Cm.reshape(Bb, nc, L, N)

    logdec = dtc * A                                   # (B,nc,L,H) <= 0
    cs = jnp.cumsum(logdec, axis=2)                    # inclusive
    # intra-chunk quadratic form: decay(j -> i) = exp(cs_i - cs_j), j <= i
    gap = cs[:, :, :, None, :] - cs[:, :, None, :, :]  # (B,nc,L,L,H)
    tri = jnp.tril(jnp.ones((L, L), bool))
    dec = jnp.where(tri[None, None, :, :, None], jnp.exp(gap), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)         # (B,nc,L,L)
    M = cb[..., None] * dec * dtc[:, :, None, :, :]    # weight dt_j at col j
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M, xc)

    # chunk-final states: sum_j exp(cs_L - cs_j) dt_j B_j (x) x_j
    dec_end = jnp.exp(cs[:, :, -1:, :] - cs)           # (B,nc,L,H)
    sb = jnp.einsum("bcjh,bcjn,bcjhp->bchnp",
                    dec_end * dtc, Bc, xc)             # (B,nc,H,N,P)
    chunk_dec = jnp.exp(cs[:, :, -1, :])               # (B,nc,H)

    def carry_fn(state, inp):
        sb_c, cd_c = inp                               # (B,H,N,P), (B,H)
        new = state * cd_c[..., None, None] + sb_c.astype(jnp.float32)
        return new, state                              # emit state BEFORE

    # the inter-chunk state recurrence runs in f32 regardless of the
    # activation dtype (bf16 decay products underflow across chunks)
    s0 = jnp.zeros((Bb, H, N, P), jnp.float32)
    final, prev = jax.lax.scan(
        carry_fn, s0, (sb.transpose(1, 0, 2, 3, 4),
                       chunk_dec.astype(jnp.float32).transpose(1, 0, 2)))
    prev = prev.transpose(1, 0, 2, 3, 4)               # (B,nc,H,N,P)

    # inter-chunk: y_i += C_i . (decay(start -> i) * prev_state)
    dec_in = jnp.exp(cs)                               # (B,nc,L,H)
    y_inter = jnp.einsum("bcin,bchnp->bcihp", Cc, prev) * dec_in[..., None]
    y = (y_intra + y_inter).reshape(Bb, nc * L, H, P)[:, :S]
    return y.astype(x.dtype), final


def apply(params: dict, u: Array, cfg: ModelConfig, *,
          bidirectional: bool = False, use_kernel: bool = False) -> Array:
    """Full-sequence forward.  u: (B, S, d)."""
    B, S, d = u.shape
    d_in, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    def one_direction(u):
        z, xBC, dt_raw = _split(params, u, cfg)
        xBC = _causal_conv(xBC, params["conv_w"], params["conv_b"])
        x, Bm, Cm = jnp.split(xBC, [d_in, d_in + N], axis=-1)
        dtv = jax.nn.softplus(dt_raw + params["dt_bias"])
        A = -jnp.exp(params["A_log"].astype(jnp.float32))
        xh = x.reshape(B, S, H, P)
        if use_kernel:
            from repro.kernels.ssd_scan import ops as ssd_ops
            y, _ = ssd_ops.ssd_scan(xh, dtv, A, Bm, Cm, chunk=cfg.ssd_chunk)
        else:
            y, _ = _ssd_scan_ref(xh, dtv, A, Bm, Cm, cfg.ssd_chunk)
        y = y + xh * params["D"][:, None]
        return _post(params, y.reshape(B, S, d_in).astype(u.dtype), z, cfg)

    y = one_direction(u)
    if bidirectional:
        y = y + jnp.flip(one_direction(jnp.flip(u, axis=1)), axis=1)
    return y


# ---------------- decode ----------------

def init_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    d_in, N = cfg.d_inner, cfg.ssm_state
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    conv_ch = d_in + 2 * N
    return {
        "state": jnp.zeros((batch, H, N, P), dtype),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype),
    }


def decode_step(params: dict, u: Array, cache: dict,
                cfg: ModelConfig) -> tuple[Array, dict]:
    """u: (B, 1, d) -> (y (B,1,d), cache)."""
    B = u.shape[0]
    d_in, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xBC, dt_raw = _split(params, u[:, 0], cfg)
    hist = jnp.concatenate([cache["conv"], xBC[:, None]], axis=1)
    w = params["conv_w"]
    xBC = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", hist[:, -w.shape[0]:], w) +
        params["conv_b"])
    x, Bm, Cm = jnp.split(xBC, [d_in, d_in + N], axis=-1)
    dtv = jax.nn.softplus(dt_raw + params["dt_bias"])          # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = x.reshape(B, H, P)
    dec = jnp.exp(dtv * A)                                     # (B,H)
    upd = jnp.einsum("bh,bn,bhp->bhnp", dtv, Bm, xh)
    state = cache["state"] * dec[..., None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", Cm, state)
    y = y + xh * params["D"][:, None]
    out = _post(params, y.reshape(B, 1, d_in).astype(u.dtype),
                z[:, None], cfg)
    return out, {"state": state.astype(cache["state"].dtype),
                 "conv": hist[:, 1:]}
