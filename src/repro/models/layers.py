"""Shared neural building blocks: norms, RoPE, MLPs, time embeddings.

Pure-functional: ``init_*`` returns a dict pytree, ``apply``-style
functions take (params, inputs).  Initializers follow standard truncated
normal / scaled schemes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / (d_in ** 0.5)
    return (jax.random.truncated_normal(key, -2, 2, (d_in, d_out)) *
            std).astype(dtype)


# ---------------- RMSNorm ----------------

def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: dict, x: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


# ---------------- RoPE ----------------

def rope_freqs(hd: int, theta: float, positions: Array) -> tuple[Array, Array]:
    """cos/sin tables (..., hd/2) for given integer positions (...,)."""
    inv = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """x: (B, S, H, hd); cos/sin: (B?, S, hd/2) broadcastable."""
    dt = x.dtype
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c],
                           axis=-1).astype(dt)


# ---------------- MLP ----------------

def mlp_init(key, d: int, d_ff: int, mlp_type: str, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {"down": dense_init(ks[2], d_ff, d, dtype)}
    if mlp_type == "swiglu":
        p["gate"] = dense_init(ks[0], d, d_ff, dtype)
        p["up"] = dense_init(ks[1], d, d_ff, dtype)
    else:
        p["up"] = dense_init(ks[1], d, d_ff, dtype)
    return p


def mlp(params: dict, x: Array, mlp_type: str) -> Array:
    if mlp_type == "swiglu":
        h = jax.nn.silu(x @ params["gate"]) * (x @ params["up"])
    else:
        h = jax.nn.gelu(x @ params["up"])
    return h @ params["down"]


# ---------------- Diffusion time embedding ----------------

def time_embed_init(key, d: int, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {"w1": dense_init(k1, d, d, dtype),
            "w2": dense_init(k2, d, d, dtype)}


def time_embed(params: dict, t: Array, d: int) -> Array:
    """Sinusoidal features of t in [0,1] -> MLP -> (B, d)."""
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) *
                    (jnp.log(10_000.0) / max(half - 1, 1)))
    ang = t.astype(jnp.float32)[:, None] * freqs[None, :] * 1000.0
    feats = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    if feats.shape[-1] < d:
        feats = jnp.pad(feats, ((0, 0), (0, d - feats.shape[-1])))
    h = jax.nn.silu(feats.astype(params["w1"].dtype) @ params["w1"])
    return h @ params["w2"]
