"""Model configuration: one dataclass describes every architecture family.

A model is a sequence of *blocks* (``block_pattern``), each one of:
  "attn"        — GQA multi-head attention (+MLP)
  "swa"         — sliding-window attention (+MLP)
  "moe"         — attention + mixture-of-experts MLP
  "mamba2"      — Mamba-2 SSD block
  "mlstm"       — xLSTM matrix-LSTM block
  "slstm"       — xLSTM scalar-LSTM block
  "shared_attn" — Zamba-style attention block with *shared* weights across
                  all its occurrences

The pattern must be periodic (``pattern == unit * k``) so the layer stack
can be run as a ``lax.scan`` over superblocks (weights stacked along the
scan axis) or fully unrolled for dry-run cost analysis.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

BlockKind = Literal["attn", "swa", "moe", "mamba2", "mlstm", "slstm",
                    "shared_attn"]

ATTN_KINDS = ("attn", "swa", "moe", "shared_attn")
SSM_KINDS = ("mamba2", "mlstm", "slstm")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                       # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    block_pattern: tuple[str, ...]

    # attention
    head_dim: int = 0                    # 0 => d_model // n_heads
    rope_theta: float = 10_000.0
    sliding_window: int = 0              # used by "swa" blocks
    attn_impl: str = "einsum"            # einsum | blocked | pallas
    attn_block_q: int = 512              # blocked/pallas tile sizes
    attn_block_k: int = 512

    # mlp
    mlp_type: str = "swiglu"             # swiglu | gelu

    # moe
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_z_weight: float = 1e-3
    load_balance_weight: float = 1e-2
    moe_dispatch: str = "global"         # global | local (per-shard sort)
    moe_local_groups: int = 16           # data-axis groups for "local"

    # ssm (mamba2)
    ssm_state: int = 64
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    ssd_chunk: int = 128

    # xlstm
    lstm_heads: int = 4
    mlstm_chunk: int = 0                 # 0 = full S^2 parallel form
    mlstm_unroll: bool = False           # unroll the chunk loop (dry-run)

    # embeddings / head
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    # diffusion-denoiser options
    time_conditioning: bool = True
    bidirectional: bool = False          # denoiser mode: no causal mask /
                                         # fwd+bwd scan fusion for SSM blocks

    # modality frontend stub (the one allowed stub)
    frontend: str | None = None          # "audio" | "vision" | None
    frontend_tokens: int = 0             # prefix positions fed by the stub

    # runtime / lowering
    dtype: str = "float32"
    scan_layers: bool = True             # False => unroll (dry-run accuracy)
    remat: bool = False
    paper: str = ""                      # provenance note

    # ---------------- derived ----------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def __post_init__(self):
        if len(self.block_pattern) != self.n_layers:
            raise ValueError(
                f"{self.name}: pattern length {len(self.block_pattern)} != "
                f"n_layers {self.n_layers}")
        self.superblock()  # validate periodicity eagerly

    def superblock(self) -> tuple[tuple[str, ...], int]:
        """Smallest repeating unit of the pattern and its repeat count."""
        pat = self.block_pattern
        L = len(pat)
        for p in range(1, L + 1):
            if L % p == 0 and pat == pat[:p] * (L // p):
                return pat[:p], L // p
        raise ValueError(f"{self.name}: non-periodic block pattern")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self, **kw) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        unit, _ = self.superblock()
        # keep one block of each distinct kind (preserves family coverage:
        # zamba -> (mamba2, shared_attn), xlstm -> (mlstm, slstm))
        seen: list[str] = []
        for kind in unit:
            if kind not in seen:
                seen.append(kind)
        unit = tuple(seen[:3])
        small = dict(
            n_layers=len(unit) * 1,
            block_pattern=unit,
            d_model=256,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_ff=512 if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 256),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            experts_per_token=min(self.experts_per_token, 2)
            if self.n_experts else 0,
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=32,
            ssd_chunk=16,
            lstm_heads=2,
            sliding_window=min(self.sliding_window, 32)
            if self.sliding_window else 0,
            frontend_tokens=min(self.frontend_tokens, 4)
            if self.frontend_tokens else 0,
            head_dim=0,
        )
        small.update(kw)
        return self.replace(**small)


def dense_pattern(n_layers: int, sliding_window: int = 0) -> tuple[str, ...]:
    return ("swa" if sliding_window else "attn",) * n_layers


def moe_pattern(n_layers: int) -> tuple[str, ...]:
    return ("moe",) * n_layers
