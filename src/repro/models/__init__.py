"""Model zoo: composable blocks + top-level Model."""
from repro.models.config import ModelConfig, dense_pattern, moe_pattern
from repro.models.model import Model

__all__ = ["ModelConfig", "Model", "dense_pattern", "moe_pattern"]
