"""Block registry: init / full-sequence apply / cache init / decode step
for every block kind, plus the residual wiring and pre-norms.

Every block has the same external contract so the model can scan or unroll
heterogeneous patterns:

  init(key, cfg)                          -> params
  apply(params, x, cfg, mode)             -> (y, aux)       # full sequence
  init_cache(cfg, batch, max_seq, dtype)  -> cache
  decode(params, x, cache, pos, cfg)      -> (y, new_cache)  # one token
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention, mamba2, moe, xlstm
from repro.models.config import ModelConfig
from repro.models.layers import mlp, mlp_init, rmsnorm, rmsnorm_init

Array = jnp.ndarray


# ---------------- attention-family blocks (attn / swa / moe / shared) ----

def _attn_init(key, cfg: ModelConfig, is_moe: bool) -> dict:
    k1, k2 = jax.random.split(key)
    dt = jnp.dtype(cfg.dtype)
    p = {"ln1": rmsnorm_init(cfg.d_model, dt),
         "attn": attention.init(k1, cfg),
         "ln2": rmsnorm_init(cfg.d_model, dt)}
    if is_moe:
        p["moe"] = moe.init(k2, cfg)
    elif cfg.d_ff > 0:
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp_type, dt)
    return p


def _attn_apply(params, x, cfg: ModelConfig, *, causal: bool, window: int,
                is_moe: bool):
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)
    x = x + attention.apply(params["attn"], h, cfg, causal=causal,
                            window=window)
    aux = {}
    h = rmsnorm(params["ln2"], x, cfg.norm_eps)
    if is_moe:
        y, aux = moe.apply(params["moe"], h, cfg)
        x = x + y
    elif "mlp" in params:
        x = x + mlp(params["mlp"], h, cfg.mlp_type)
    return x, aux


def _attn_decode(params, x, cache, pos, cfg: ModelConfig, *, window: int,
                 is_moe: bool):
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)
    y, cache = attention.decode_step(params["attn"], h, cache, pos, cfg,
                                     window=window)
    x = x + y
    h = rmsnorm(params["ln2"], x, cfg.norm_eps)
    if is_moe:
        y, _ = moe.apply(params["moe"], h, cfg)
        x = x + y
    elif "mlp" in params:
        x = x + mlp(params["mlp"], h, cfg.mlp_type)
    return x, cache


# ---------------- dispatch ----------------

def init(kind: str, key, cfg: ModelConfig) -> dict:
    if kind in ("attn", "swa", "shared_attn"):
        return _attn_init(key, cfg, is_moe=False)
    if kind == "moe":
        return _attn_init(key, cfg, is_moe=True)
    dt = jnp.dtype(cfg.dtype)
    k1, k2 = jax.random.split(key)
    ln = rmsnorm_init(cfg.d_model, dt)
    if kind == "mamba2":
        return {"ln": ln, "mixer": mamba2.init(k1, cfg)}
    if kind == "mlstm":
        return {"ln": ln, "mixer": xlstm.mlstm_init(k1, cfg)}
    if kind == "slstm":
        return {"ln": ln, "mixer": xlstm.slstm_init(k1, cfg)}
    raise KeyError(kind)


def apply(kind: str, params: dict, x: Array, cfg: ModelConfig, *,
          causal: bool) -> tuple[Array, dict]:
    bidir = not causal
    # "swa" blocks always window; "moe" blocks window when configured
    # (Mixtral: SWA + MoE in the same layer)
    window = cfg.sliding_window if kind in ("swa", "moe") else 0
    if kind in ("attn", "swa", "shared_attn", "moe"):
        return _attn_apply(params, x, cfg, causal=causal, window=window,
                           is_moe=(kind == "moe"))
    h = rmsnorm(params["ln"], x, cfg.norm_eps)
    if kind == "mamba2":
        y = mamba2.apply(params["mixer"], h, cfg, bidirectional=bidir)
    elif kind == "mlstm":
        y = xlstm.mlstm_apply(params["mixer"], h, cfg, bidirectional=bidir)
    elif kind == "slstm":
        y = xlstm.slstm_apply(params["mixer"], h, cfg, bidirectional=bidir)
    else:
        raise KeyError(kind)
    return x + y, {}


def init_cache(kind: str, cfg: ModelConfig, batch: int, max_seq: int,
               dtype) -> dict:
    if kind in ("attn", "shared_attn"):
        return attention.init_cache(cfg, batch, max_seq, 0, dtype)
    if kind in ("swa", "moe"):
        return attention.init_cache(cfg, batch, max_seq,
                                    cfg.sliding_window, dtype)
    if kind == "mamba2":
        return mamba2.init_cache(cfg, batch, dtype)
    if kind == "mlstm":
        return xlstm.mlstm_init_cache(cfg, batch, dtype)
    if kind == "slstm":
        return xlstm.slstm_init_cache(cfg, batch, dtype)
    raise KeyError(kind)


def decode(kind: str, params: dict, x: Array, cache: dict, pos: Array,
           cfg: ModelConfig) -> tuple[Array, dict]:
    if kind in ("attn", "shared_attn"):
        return _attn_decode(params, x, cache, pos, cfg, window=0,
                            is_moe=False)
    if kind in ("swa", "moe"):
        return _attn_decode(params, x, cache, pos, cfg,
                            window=cfg.sliding_window,
                            is_moe=(kind == "moe"))
    h = rmsnorm(params["ln"], x, cfg.norm_eps)
    if kind == "mamba2":
        y, cache = mamba2.decode_step(params["mixer"], h, cache, cfg)
    elif kind == "mlstm":
        y, cache = xlstm.mlstm_decode(params["mixer"], h, cache, cfg)
    elif kind == "slstm":
        y, cache = xlstm.slstm_decode(params["mixer"], h, cache, cfg)
    else:
        raise KeyError(kind)
    return x + y, cache
