"""Top-level Model: embeddings, superblock stack (scan or unrolled),
diffusion time conditioning, frontend fusion, LM head, KV/state caches.

The block pattern is decomposed into ``unit * n_super`` (config enforces
periodicity).  Non-shared block weights are stacked along a leading
``n_super`` axis and the stack runs as one ``lax.scan`` (fast compiles) or
fully unrolled (``scan_layers=False`` — accurate dry-run cost analysis).
``shared_attn`` blocks hold a single weight set used by every occurrence
(Zamba-style), while each occurrence gets its own cache slot.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import blocks, frontend
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, rmsnorm, rmsnorm_init, time_embed, time_embed_init

Array = jnp.ndarray


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.unit, self.n_super = cfg.superblock()

    # ---------------- init ----------------

    def init(self, key) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        keys = jax.random.split(key, 4 + len(self.unit))
        params: dict = {
            "embed": dense_init(keys[0], cfg.vocab_size, cfg.d_model, dt,
                                scale=cfg.vocab_size ** 0.5 * 0.02),
            "ln_f": rmsnorm_init(cfg.d_model, dt),
        }
        if not cfg.tie_embeddings:
            params["head"] = dense_init(keys[1], cfg.d_model,
                                        cfg.vocab_size, dt)
        if cfg.time_conditioning:
            params["time"] = time_embed_init(keys[2], cfg.d_model, dt)
        if "shared_attn" in self.unit:
            params["shared"] = blocks.init("shared_attn", keys[3], cfg)

        unit_params = {}
        for i, kind in enumerate(self.unit):
            if kind == "shared_attn":
                continue
            ks = jax.random.split(keys[4 + i], self.n_super)
            stacked = [blocks.init(kind, ks[j], cfg)
                       for j in range(self.n_super)]
            unit_params[f"b{i}"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *stacked)
        params["unit"] = unit_params
        return params

    # ---------------- full-sequence forward ----------------

    def forward(self, params: dict, tokens: Array, t: Array | None = None,
                frontend_embeds: Array | None = None,
                causal: bool | None = None) -> tuple[Array, dict]:
        """tokens: (B, S) -> (logits (B, S, V), aux losses)."""
        cfg = self.cfg
        if causal is None:
            causal = not cfg.bidirectional
        h = params["embed"][tokens]
        if t is not None and cfg.time_conditioning:
            h = h + time_embed(params["time"], t, cfg.d_model)[:, None]
        h = frontend.fuse(h, frontend_embeds)

        def superblock(h, unit_slice):
            aux_tot = jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)
            lb, rz = aux_tot
            for i, kind in enumerate(self.unit):
                p = (params["shared"] if kind == "shared_attn"
                     else unit_slice[f"b{i}"])
                h, aux = blocks.apply(kind, p, h, cfg, causal=causal)
                if aux:
                    lb = lb + aux["load_balance"]
                    rz = rz + aux["router_z"]
            return h, (lb, rz)

        body = superblock
        if cfg.remat:
            body = jax.checkpoint(superblock)

        if cfg.scan_layers:
            h, (lbs, rzs) = jax.lax.scan(body, h, params["unit"])
            lb, rz = lbs.sum(), rzs.sum()
        else:
            lb = rz = jnp.zeros((), jnp.float32)
            for j in range(self.n_super):
                sl = jax.tree.map(lambda x: x[j], params["unit"])
                h, (lb_j, rz_j) = body(h, sl)
                lb, rz = lb + lb_j, rz + rz_j

        h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
        logits = h @ (params["embed"].T if cfg.tie_embeddings
                      else params["head"])
        return logits, {"load_balance": lb, "router_z": rz}

    # ---------------- diffusion denoiser adapter ----------------

    def denoise_fn(self, params: dict, cond: dict | None = None):
        """Wrap into the samplers' ``denoise_fn(x_t, t, cond)`` contract.

        ``cond`` may hold {"prefix_tokens": (B, P)} for conditional
        generation (source prefix stays clean; logits returned for the
        target segment only) and {"frontend_embeds": ...}.
        """
        def fn(x_t, t, cond_rt):
            c = cond_rt if cond_rt is not None else (cond or {})
            fe = c.get("frontend_embeds")
            prefix = c.get("prefix_tokens")
            if prefix is not None:
                full = jnp.concatenate([prefix, x_t], axis=1)
                logits, _ = self.forward(params, full, t, fe, causal=False)
                return logits[:, prefix.shape[1]:]
            logits, _ = self.forward(params, x_t, t, fe, causal=False)
            return logits
        return fn

    # ---------------- decode (serving) ----------------

    def init_cache(self, batch: int, max_seq: int, dtype=None) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(dtype or cfg.dtype)
        cache = {}
        for i, kind in enumerate(self.unit):
            per = [blocks.init_cache(kind, cfg, batch, max_seq, dt)
                   for _ in range(self.n_super)]
            cache[f"b{i}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
        return cache

    def decode_step(self, params: dict, token: Array, cache: dict,
                    pos: Array) -> tuple[Array, dict]:
        """token: (B, 1) int32; pos: scalar int32.  Returns (logits (B,1,V),
        new cache).  Runs the stack causally with per-layer caches."""
        cfg = self.cfg
        h = params["embed"][token]

        def superblock(h, slices):
            unit_slice, cache_slice = slices
            new_cache = {}
            for i, kind in enumerate(self.unit):
                p = (params["shared"] if kind == "shared_attn"
                     else unit_slice.get(f"b{i}"))
                h, new_cache[f"b{i}"] = blocks.decode(
                    kind, p, h, cache_slice[f"b{i}"], pos, cfg)
            return h, new_cache

        if cfg.scan_layers:
            unit_wo_shared = params["unit"]
            # shared params are closed over; scan consumes (params, cache)
            def body(h, xs):
                return superblock(h, xs)
            h, new_cache = jax.lax.scan(body, h, (unit_wo_shared, cache))
        else:
            outs = []
            for j in range(self.n_super):
                psl = jax.tree.map(lambda x: x[j], params["unit"])
                csl = jax.tree.map(lambda x: x[j], cache)
                h, nc = superblock(h, (psl, csl))
                outs.append(nc)
            new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

        h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
        logits = h @ (params["embed"].T if cfg.tie_embeddings
                      else params["head"])
        return logits, new_cache

    # ---------------- bookkeeping ----------------

    def param_count(self, params) -> int:
        return sum(int(p.size) for p in jax.tree.leaves(params))

    def active_param_count(self, params) -> int:
        """MoE-aware: router + active experts only (for 6*N_active*D)."""
        cfg = self.cfg
        total = self.param_count(params)
        if not cfg.n_experts:
            return total
        moe_leaves = 0
        for i, kind in enumerate(self.unit):
            if kind != "moe":
                continue
            sub = params["unit"][f"b{i}"]["moe"]
            for name in ("gate", "up", "down"):
                if name in sub:
                    moe_leaves += int(sub[name].size)
        inactive = moe_leaves * (1 - cfg.experts_per_token / cfg.n_experts)
        return int(total - inactive)
