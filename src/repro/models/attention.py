"""Grouped-query attention with RoPE, sliding windows and KV caches.

Three interchangeable inner implementations (``cfg.attn_impl``):
  einsum  — naive S^2 attention (baseline for the roofline memory term)
  blocked — online-softmax over KV chunks in pure JAX (lax.scan); the
            memory-bounded TPU-shaped algorithm and the oracle for the
            Pallas flash kernel
  pallas  — kernels/flash_attention (interpret=True on CPU)

Mask semantics: ``causal`` plus optional ``sliding_window`` (only the last
W positions visible).  The diffusion denoiser runs with causal=False.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, dense_init, rope_freqs

Array = jnp.ndarray
NEG = -1e9


def init(key, cfg: ModelConfig) -> dict:
    hd = cfg.hd
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    return {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, dt),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, dt),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, dt),
        "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, dt,
                         scale=1.0 / max(cfg.n_layers, 1) ** 0.5),
    }


def _qkv(params, x, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    hd = cfg.hd
    q = (x @ params["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (x @ params["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v = (x @ params["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    cos, sin = rope_freqs(hd, cfg.rope_theta, positions)
    return apply_rope(q, cos, sin), apply_rope(k, cos, sin), v


def _repeat_kv(k: Array, n_heads: int) -> Array:
    """(B,S,KV,hd) -> (B,S,H,hd) by repeating each kv head H/KV times."""
    B, S, KV, hd = k.shape
    rep = n_heads // KV
    return jnp.repeat(k, rep, axis=2) if rep > 1 else k


def _mask_bias(q_pos: Array, k_pos: Array, causal: bool,
               window: int) -> Array:
    """(..., Sq, Sk) additive bias from position grids."""
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    ok = jnp.ones(diff.shape, bool)
    if causal:
        ok &= diff >= 0
    if window > 0:
        ok &= jnp.abs(diff) < window if not causal else diff < window
    return jnp.where(ok, 0.0, NEG)


def _einsum_attn(q, k, v, bias):
    hd = q.shape[-1]
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (hd ** 0.5)
    logits = logits + bias[:, None] if bias.ndim == 3 else logits + bias
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def _blocked_attn(q, k, v, bias, block_k: int, unroll: bool = False):
    """Online-softmax over KV chunks; O(S * block_k) live memory.

    ``unroll=True`` runs the chunk loop as straight-line code instead of
    ``lax.scan`` — used by the dry-run so XLA cost analysis counts every
    chunk (scan bodies are costed once).
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    bk = min(block_k, Sk)
    n_blocks = -(-Sk // bk)
    pad = n_blocks * bk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bias = jnp.pad(bias, ((0, 0), (0, 0), (0, pad)),
                       constant_values=NEG)
    kb = k.reshape(B, n_blocks, bk, H, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, n_blocks, bk, H, hd).transpose(1, 0, 2, 3, 4)
    biasb = bias.reshape(B, Sq, n_blocks, bk).transpose(2, 0, 1, 3)

    def body(carry, inp):
        m, l, acc = carry                       # (B,H,Sq), (B,H,Sq), (B,Sq,H,hd)
        kc, vc, bc = inp
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kc) / (hd ** 0.5)
        s = s.astype(jnp.float32) + bc[:, None]
        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(-1)
        acc = acc * alpha.transpose(0, 2, 1)[..., None] + jnp.einsum(
            "bhqk,bkhd->bqhd", p.astype(q.dtype), vc).astype(jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, H, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, Sq, H, hd), jnp.float32)
    if unroll:
        carry = (m0, l0, a0)
        for i in range(n_blocks):
            carry, _ = body(carry, (kb[i], vb[i], biasb[i]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, biasb))
    l = jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return (acc / l).astype(q.dtype)


def _inner(q, k, v, bias, cfg: ModelConfig):
    k = _repeat_kv(k, cfg.n_heads)
    v = _repeat_kv(v, cfg.n_heads)
    if cfg.attn_impl == "blocked":
        return _blocked_attn(q, k, v, bias, cfg.attn_block_k)
    if cfg.attn_impl == "blocked_unrolled":
        return _blocked_attn(q, k, v, bias, cfg.attn_block_k, unroll=True)
    if cfg.attn_impl == "pallas":
        from repro.kernels.flash_attention import ops as flash_ops
        return flash_ops.flash_attention(
            q, k, v, bias, block_q=cfg.attn_block_q,
            block_k=cfg.attn_block_k)
    return _einsum_attn(q, k, v, bias)


def apply(params: dict, x: Array, cfg: ModelConfig, *, causal: bool,
          window: int = 0) -> Array:
    """Full-sequence attention.  x: (B, S, d)."""
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _qkv(params, x, cfg, positions)
    bias = _mask_bias(jnp.arange(S), jnp.arange(S), causal, window)
    bias = jnp.broadcast_to(bias, (B, S, S))
    y = _inner(q, k, v, bias, cfg)
    return y.reshape(B, S, cfg.n_heads * cfg.hd) @ params["wo"]


# ---------------- KV cache decode ----------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int, window: int,
               dtype) -> dict:
    """Physical cache length: the window for SWA blocks, else max_seq."""
    L = min(max_seq, window) if window else max_seq
    hd = cfg.hd
    return {
        "k": jnp.zeros((batch, L, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, L, cfg.n_kv_heads, hd), dtype),
    }


def decode_step(params: dict, x: Array, cache: dict, pos: Array,
                cfg: ModelConfig, window: int = 0) -> tuple[Array, dict]:
    """One-token decode.  x: (B, 1, d); pos: scalar int32 (current index).

    The cache is a ring buffer of physical length L; slot = pos mod L.
    """
    B = x.shape[0]
    hd = cfg.hd
    L = cache["k"].shape[1]
    positions = jnp.broadcast_to(pos[None], (B, 1))
    q, k_new, v_new = _qkv(params, x, cfg, positions)
    slot = jnp.mod(pos, L)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
    # absolute position held by each physical slot: the latest write sits
    # at `slot` with position `pos`; slot i holds pos - ((slot - i) mod L)
    idx = jnp.arange(L)
    k_pos = pos - jnp.mod(slot - idx, L)
    valid = k_pos >= 0
    bias = _mask_bias(pos[None], k_pos, causal=True, window=window)
    bias = jnp.where(valid[None, :], bias, NEG)
    bias = jnp.broadcast_to(bias, (B, 1, L))
    y = _inner(q, k, v, bias, cfg)
    y = y.reshape(B, 1, cfg.n_heads * hd) @ params["wo"]
    return y, {"k": k, "v": v}
