"""Mixture-of-Experts MLP with sort-based capacity dispatch.

Top-k routing (Mixtral: 8e top-2; Llama-4-Maverick: 128e top-1).  Tokens
are dispatched to per-expert buffers of capacity
``C = ceil(top_k * tokens / E * capacity_factor)`` via an argsort on
expert id (TPU-friendly: two sorts + gathers, no (T, E, C) one-hot).
Overflowing tokens are dropped (their expert contribution is zero — the
residual path still carries them), matching standard capacity routing.

Expert FFNs run as a single batched einsum over stacked weights
(E, d, ff): with expert-parallel sharding on the model axis this is the
all-to-all pattern the roofline's collective term tracks.

Auxiliary losses: router z-loss and load-balance loss (returned, weighted
by the trainer).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init

Array = jnp.ndarray


def init(key, cfg: ModelConfig) -> dict:
    E, d, ff = cfg.n_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    std = 1.0 / d ** 0.5
    p = {
        "router": dense_init(ks[0], d, E, dt, scale=0.1),
        "down": (jax.random.truncated_normal(ks[3], -2, 2, (E, ff, d)) *
                 (1.0 / ff ** 0.5)).astype(dt),
    }
    if cfg.mlp_type == "swiglu":
        p["gate"] = (jax.random.truncated_normal(ks[1], -2, 2, (E, d, ff)) *
                     std).astype(dt)
    p["up"] = (jax.random.truncated_normal(ks[2], -2, 2, (E, d, ff)) *
               std).astype(dt)
    return p


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(cfg.experts_per_token * n_tokens / cfg.n_experts
            * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)        # round up to 8 for tiling


def apply(params: dict, x: Array, cfg: ModelConfig) -> tuple[Array, dict]:
    """x: (B, S, d) -> (y, aux_losses).

    ``cfg.moe_dispatch == "local"`` splits the tokens into
    ``moe_local_groups`` groups (aligned with the data-parallel shards)
    and dispatches *within* each group: every op is batched over the
    sharded leading group dim, so GSPMD never has to reason across
    shards through the sort — the §Perf fix for the collective-bound
    MoE baselines.  With ample capacity both dispatches compute the
    same token-expert assignments.
    """
    B, S, d = x.shape
    T = B * S
    G = cfg.moe_local_groups
    if cfg.moe_dispatch == "shard_map":
        y, aux = _apply_shard_map(params, x, cfg)
        if y is not None:
            return y, aux
        # no ambient mesh (unit tests / single device): fall through
    if cfg.moe_dispatch == "local" and G > 1 and T % G == 0:
        xg = x.reshape(G, T // G, d)
        C = capacity(cfg, T // G)
        y, aux = jax.vmap(lambda xt: _dispatch_ffn(params, xt, cfg, C))(xg)
        return (y.reshape(B, S, d),
                jax.tree.map(lambda a: a.mean(0), aux))
    C = capacity(cfg, T)
    y, aux = _dispatch_ffn(params, x.reshape(T, d), cfg, C)
    return y.reshape(B, S, d), aux


def _apply_shard_map(params: dict, x: Array, cfg: ModelConfig):
    """§Perf: shard_map MoE — the GSPMD-proof dispatch.

    The sort-based dispatch defeats GSPMD's sharding propagation (it
    replicates the expert buffers across the data axis and all-gathers
    the tokens).  Inside shard_map every op is *local by construction*:
    tokens stay on their data shard, dispatch/sort run per shard, expert
    FFNs run on the local (E, d, ff/m) tensor-parallel weight shards, and
    the only collective is one explicit psum over the model axis for the
    ff contraction.  Requires an ambient mesh (``jax.set_mesh``);
    returns (None, None) when there is none so callers can fall back.
    """
    am = jax.sharding.get_abstract_mesh()
    if am is None or not am.axis_names or "model" not in am.axis_names:
        return None, None
    from jax.sharding import PartitionSpec as P
    axes = am.axis_names
    dax = tuple(a for a in axes if a != "model")
    B, S, d = x.shape
    n_data = 1
    for a in dax:
        n_data *= am.shape[a]
    if B % n_data or cfg.d_ff % am.shape["model"]:
        return None, None
    T_loc = (B // n_data) * S
    C = capacity(cfg, T_loc)
    m = am.shape["model"]
    # expert-parallel when experts divide the model axis (llama4: 128/16)
    # — tokens travel to their experts via all-to-all; otherwise
    # tensor-parallel expert weights with one psum on the ff contraction.
    ep = bool(cfg.n_experts % m == 0 and cfg.n_experts >= m)

    if ep:
        w_specs = {"router": P(), "up": P("model", None, None),
                   "down": P("model", None, None)}
        if cfg.mlp_type == "swiglu":
            w_specs["gate"] = P("model", None, None)
    else:
        w_specs = {"router": P(), "up": P(None, None, "model"),
                   "down": P(None, "model", None)}
        if cfg.mlp_type == "swiglu":
            w_specs["gate"] = P(None, None, "model")
    in_specs = ({k: w_specs[k] for k in params},
                P(dax if len(dax) > 1 else dax[0], None, None))
    out_specs = (P(dax if len(dax) > 1 else dax[0], None, None),
                 {"load_balance": P(), "router_z": P(),
                  "dropped_frac": P()})

    def local_fn(p, xl):
        Bl, Sl, dl = xl.shape
        xt = xl.reshape(Bl * Sl, dl)
        if ep:
            # activations are replicated over "model" (TP elsewhere), so
            # each model-rank takes its 1/m token slice, dispatches via
            # all-to-all, and an all-gather rebuilds the full activation
            mi = jax.lax.axis_index("model")
            Tm = xt.shape[0] // m
            xt_m = jax.lax.dynamic_slice_in_dim(xt, mi * Tm, Tm)
            y_m, aux = _dispatch_ffn_ep(p, xt_m, cfg,
                                        capacity(cfg, Tm), "model")
            y = jax.lax.all_gather(y_m, "model", axis=0, tiled=True)
        else:
            y, aux = _dispatch_ffn(p, xt, cfg, C)
            y = jax.lax.psum(y, "model")      # ff-contraction partials
        aux = jax.tree.map(lambda a: jax.lax.pmean(a, dax), aux)
        return y.reshape(Bl, Sl, dl), aux

    return jax.shard_map(local_fn, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)(params, x)


def _route(params: dict, xt: Array, cfg: ModelConfig, C: int):
    """Sort-based capacity routing: tokens -> (E, C, d) expert buffers.

    Returns (buffers h, combine-state dict, aux losses)."""
    T, d = xt.shape
    E, K = cfg.n_experts, cfg.experts_per_token

    logits = (xt @ params["router"]).astype(jnp.float32)     # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)          # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # flatten the K assignments, sort by expert id (stable => FIFO rank)
    flat_e = expert_idx.reshape(-1)                          # (T*K,)
    flat_tok = jnp.repeat(jnp.arange(T), K)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_tok[order], flat_gate[order]
    # rank within expert = position - first position of that expert
    pos = jnp.arange(T * K)
    counts = jnp.bincount(se, length=E)
    starts = jnp.cumsum(counts) - counts
    rank = pos - starts[se]
    keep = rank < C
    slot = jnp.where(keep, se * C + rank, E * C)             # E*C = trash

    buf = jnp.zeros((E * C + 1, d), xt.dtype).at[slot].set(xt[st])
    h = buf[: E * C].reshape(E, C, d)

    me = probs.mean(0)                                       # (E,)
    fe = jnp.bincount(flat_e, length=E) / (T * K)
    aux = {"load_balance": (E * jnp.sum(me * fe)).astype(jnp.float32),
           "router_z": jnp.mean(
               jax.nn.logsumexp(logits, -1) ** 2).astype(jnp.float32),
           "dropped_frac": 1.0 - keep.mean()}
    state = {"st": st, "sg": sg, "keep": keep, "slot": slot, "T": T}
    return h, state, aux


def _expert_ffn(params: dict, h: Array, cfg: ModelConfig) -> Array:
    """(E, C, d) -> (E, C, d) through the per-expert (Sw)iGLU FFN."""
    if cfg.mlp_type == "swiglu":
        a = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, params["gate"]))
        h = a * jnp.einsum("ecd,edf->ecf", h, params["up"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", h, params["up"]))
    return jnp.einsum("ecf,efd->ecd", h, params["down"])


def _combine(out: Array, state: dict, dtype) -> Array:
    """(E, C, d) expert outputs -> (T, d) gated token outputs."""
    EC, d = out.shape[0] * out.shape[1], out.shape[2]
    out = out.reshape(EC, d)
    keep, slot, st, sg = (state["keep"], state["slot"], state["st"],
                          state["sg"])
    gathered = jnp.where(keep[:, None], out[jnp.minimum(slot, EC - 1)], 0.0)
    return jnp.zeros((state["T"], d), dtype).at[st].add(
        gathered * sg[:, None].astype(dtype))


def _dispatch_ffn(params: dict, xt: Array, cfg: ModelConfig,
                  C: int) -> tuple[Array, dict]:
    """Route + expert FFN + combine on (T, d) tokens (single device /
    tensor-parallel weight shards)."""
    h, state, aux = _route(params, xt, cfg, C)
    out = _expert_ffn(params, h, cfg)
    return _combine(out, state, xt.dtype), aux


def _dispatch_ffn_ep(params: dict, xt: Array, cfg: ModelConfig, C: int,
                     model_axis: str) -> tuple[Array, dict]:
    """Expert-parallel dispatch inside shard_map: the canonical MoE
    all-to-all.  Weights hold E/m experts per chip; token buffers are
    exchanged over the model axis (split experts, concat capacity), the
    local experts run at full d_ff, and a reverse all-to-all brings the
    outputs home.  Collectives: exactly 2 x buffer bytes per layer."""
    h, state, aux = _route(params, xt, cfg, C)               # (E, C, d)
    # -> (E_loc, m*C, d): every chip receives its experts' tokens from
    # every model-rank of its data shard
    h = jax.lax.all_to_all(h, model_axis, split_axis=0, concat_axis=1,
                           tiled=True)
    out = _expert_ffn(params, h, cfg)
    out = jax.lax.all_to_all(out, model_axis, split_axis=1, concat_axis=0,
                             tiled=True)                     # (E, C, d)
    return _combine(out, state, xt.dtype), aux
