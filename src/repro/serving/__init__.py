"""Serving substrate: generation engine + request batching."""
from repro.serving.engine import EngineConfig, GenerationEngine
from repro.serving.scheduler import BatchScheduler, Request

__all__ = ["EngineConfig", "GenerationEngine", "BatchScheduler", "Request"]
