"""Serving substrate: generation engine + request batching (drain-mode
and continuous NFE-aware)."""
from repro.serving.engine import (EngineConfig, GenerationEngine,
                                  StepwiseRunner)
from repro.serving.scheduler import (BatchScheduler, ContinuousScheduler,
                                     Request)

__all__ = ["EngineConfig", "GenerationEngine", "StepwiseRunner",
           "BatchScheduler", "ContinuousScheduler", "Request"]
