"""Generation engine: one object that binds (model params, sampler family)
and serves batched requests.

The engine has no per-method branches: every sampler is dispatched
through ``repro.core.samplers.registry``, so the benchmarks and the
serving launcher compare apples-to-apples and a newly registered sampler
is immediately servable (``registry.names()`` is the method list).

For conditional requests, ``cond={"prefix_tokens": src}``: the model
wrapper feeds [src | x_t] with bidirectional attention and returns target
logits, so samplers stay prefix-agnostic.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import decode as decode_lib
from repro.core import schedules as sched_lib
from repro.core import transition as trans_lib
from repro.core.noise import NoiseDist
from repro.core.samplers import SamplerConfig, SamplerOutput, registry
from repro.core.samplers.stepwise import CallSchedule
from repro.models.model import Model


@dataclasses.dataclass
class EngineConfig:
    method: str = "dndm"
    steps: int = 50                   # T for discrete methods / MP iters
    schedule: str = "linear"
    noise_kind: str = "absorbing"
    beta: tuple[float, float] | None = None   # Beta approx of D_tau
    nfe_budget: int = 0               # static variants
    x0_mode: str = "sample"
    temperature: float = 1.0
    order: str = "iid"                # iid | l2r | r2l
    shared_tau: bool = True           # one tau-set per batch (paper NFE)
    ddim_stride: int = 1              # DDIM baseline subsequence stride


class GenerationEngine:
    def __init__(self, model: Model, params, engine_cfg: EngineConfig):
        self.model = model
        self.params = params
        self.cfg = engine_cfg
        v = model.cfg.vocab_size
        if engine_cfg.noise_kind == "absorbing":
            from repro.core.noise import absorbing
            self.noise: NoiseDist = absorbing(v)
        else:
            from repro.core.noise import multinomial
            self.noise = multinomial(v)
        self.check_method(engine_cfg.method)    # fail fast, list alternatives
        self.denoise_fn = model.denoise_fn(params)
        self._law_cache: dict = {}
        self._jit_cache: dict = {}
        self._host_warm: set = set()    # host-sampler per-step jit warm keys

    def check_method(self, name: str) -> registry.SamplerSpec:
        """Resolve a method and validate it against the engine's noise
        kind (also used by the scheduler before enqueueing overrides)."""
        spec = registry.get(name)
        noise = getattr(self, "noise", None)
        if noise is not None and noise.kind not in spec.noise_kinds:
            raise ValueError(
                f"{spec.name} supports {sorted(spec.noise_kinds)} noise, "
                f"engine is configured with {noise.kind!r}")
        return spec

    def _laws(self):
        """(schedule, dist, cdist) derived from the *current* config —
        mutating steps/schedule/beta must never serve stale laws."""
        c = self.cfg
        lk = (c.schedule, c.steps, c.beta)
        if lk not in self._law_cache:
            schedule = sched_lib.get(c.schedule, c.steps)
            if c.beta:
                a, b = c.beta
                dist = trans_lib.beta_approx(c.steps, a, b)
                cdist = trans_lib.beta_continuous(a, b)
            else:
                dist = trans_lib.from_schedule(schedule)
                cdist = trans_lib.beta_continuous(17, 4)
            self._law_cache[lk] = (schedule, dist, cdist)
        return self._law_cache[lk]

    def runtime(self) -> registry.SamplerRuntime:
        c = self.cfg
        schedule, dist, cdist = self._laws()
        return registry.SamplerRuntime(
            denoise_fn=self.denoise_fn, noise=self.noise,
            schedule=schedule, dist=dist, cdist=cdist,
            cfg=SamplerConfig(x0_mode=c.x0_mode, temperature=c.temperature),
            steps=c.steps, nfe_budget=c.nfe_budget, order=c.order,
            shared_tau=c.shared_tau, ddim_stride=c.ddim_stride)

    def _cache_key(self, method: str, batch: int, N: int,
                   rt: registry.SamplerRuntime, cond: dict | None):
        # every knob that changes the traced computation must be in the
        # key — reconfiguring the engine (steps, beta, nfe_budget, order,
        # ...) must never serve a stale compiled sampler.  cond structure
        # is part of the key too: the cached callable is AOT-compiled, so
        # it is specialized to the conditioning shapes/dtypes.
        c = self.cfg
        cond_key = None if cond is None else tuple(
            sorted((k, v.shape, str(v.dtype)) for k, v in cond.items()))
        return (method, batch, N, c.schedule, c.beta, rt.steps,
                rt.nfe_budget, rt.order, rt.shared_tau, rt.ddim_stride,
                rt.cfg, cond_key)

    def generate(self, key, batch: int, N: int, cond: dict | None = None,
                 method: str | None = None):
        """Returns (SamplerOutput, wall_seconds).

        ``method`` overrides the engine's configured sampler per call —
        one engine instance can serve every registered method.

        ``wall_seconds`` measures steady-state execution only, for both
        sampler kinds.  Scan samplers compile a jit-cache miss ahead of
        the timed run (``.lower().compile()``); host samplers run the
        sampler once untimed on the first call per (shape, knob) key so
        the per-step jit caches are warm, then time a second run under
        the same PRNG key (identical output).  Either way the one-time
        cost is reported as ``aux["compile_seconds"]`` (0.0 on a warm
        key), so benchmarks never attribute trace time to the sampler.

        With ``repro.obs`` enabled, every call is an ``engine.generate``
        trace span (method/kind/batch/seq + nfe/wall/cache/backend) and
        feeds the engine.* metrics; ``REPRO_JAX_PROFILE=dir``
        additionally captures a ``jax.profiler`` device trace.
        """
        m = method or self.cfg.method
        spec = self.check_method(m)
        rt = self.runtime()
        with obs.span("engine.generate", method=m, kind=spec.kind,
                      batch=batch, seq=N) as sp, obs.maybe_jax_profile():
            out, wall, cache = self._run(key, spec, m, rt, batch, N, cond)
            if obs.enabled():
                backend = decode_lib.resolve_backend()
                compile_s = out.aux.get("compile_seconds", 0.0)
                obs.counter("engine.requests").inc(method=m, kind=spec.kind)
                obs.counter("engine.nfe").inc(out.nfe, method=m)
                obs.counter("engine.tokens").inc(batch * N, method=m)
                obs.histogram("engine.wall_seconds").observe(wall, method=m)
                if compile_s:
                    obs.histogram("engine.compile_seconds").observe(
                        compile_s, method=m, kind=spec.kind)
                sp.set(nfe=out.nfe, wall_s=wall, compile_s=compile_s,
                       cache=cache, backend=backend)
        return out, wall

    def plan_request(self, key, N: int,
                     method: str | None = None) -> CallSchedule:
        """The request's predetermined call schedule, known at admission.

        DNDM's structural claim as an API: sampling the transition-time
        set under ``key`` determines every network call the request will
        ever make (times, per-call key stream, x_T) before sampling
        starts.  The continuous scheduler calls this at ``submit()``.
        """
        m = method or self.cfg.method
        spec = self.check_method(m)
        if spec.schedule_fn is None:
            raise ValueError(f"{m} does not expose a call schedule")
        return spec.schedule_fn(key, self.runtime(), N)

    def stepwise(self, rows: int, N: int, method: str | None = None,
                 prefix_len: int = 0) -> "StepwiseRunner":
        """A row-resumable runner: ``rows`` independent request slots of
        length ``N``, advanced one own-schedule step per batched call.
        ``prefix_len > 0`` makes it a conditional runner — every admitted
        request must carry a prefix of exactly that length."""
        return StepwiseRunner(self, method or self.cfg.method, rows, N,
                              prefix_len=prefix_len)

    def _run(self, key, spec, m: str, rt, batch: int, N: int, cond):
        """Dispatch one request; returns (out, steady wall, hit|miss)."""
        ck = self._cache_key(m, batch, N, rt, cond)
        if spec.kind == "host":
            # host-driven: data-dependent NFE, per-step jit inside the
            # sampler module hits its own cache.  A cold key folds the
            # per-step trace time into the first walk, so warm it with
            # one untimed run — the timed run repeats the same key and
            # returns the identical output.
            missed = ck not in self._host_warm
            warm_wall = 0.0
            if missed:
                tc = time.time()
                # the warm-up re-executes the exact run measured below;
                # recording it would double-count sampler.step events,
                # step/reveal histograms and decode.* counters on every
                # jit-cache miss, so obs is suppressed for its duration
                with obs.suppressed():
                    warm = spec.run(key, rt, batch, N, cond)
                    jax.block_until_ready(warm.tokens)
                warm_wall = time.time() - tc
                self._host_warm.add(ck)
            t0 = time.time()
            out = spec.run(key, rt, batch, N, cond)
            jax.block_until_ready(out.tokens)
            wall = time.time() - t0
            # estimated per-step jit warm-up: cold walk minus steady walk
            out.aux["compile_seconds"] = (max(0.0, warm_wall - wall)
                                          if missed else 0.0)
        else:
            # scan-based samplers have a statically known NFE, so the
            # whole sampler is AOT-compiled once per (shape, knobs, cond
            # structure) and reused across requests.
            compile_s = 0.0
            missed = ck not in self._jit_cache
            if missed:
                run = spec.run
                tc = time.time()
                call = jax.jit(
                    lambda k, c: run(k, rt, batch, N, c).tokens,
                ).lower(key, cond).compile()
                compile_s = time.time() - tc
                self._jit_cache[ck] = (call, spec.static_nfe(rt, N))
            call, nfe = self._jit_cache[ck]
            t0 = time.time()        # timed run starts after compilation
            out = SamplerOutput(tokens=call(key, cond), nfe=nfe,
                                aux={"compile_seconds": compile_s})
            jax.block_until_ready(out.tokens)
            wall = time.time() - t0
        name = ("engine.jit_cache.misses" if missed
                else "engine.jit_cache.hits")
        obs.counter(name).inc(method=m, kind=spec.kind)
        return out, wall, ("miss" if missed else "hit")


class StepwiseRunner:
    """Fixed-shape rolling batch of row-resumable requests.

    ``rows`` slots share one compiled batched step; each occupied slot
    carries a request's :class:`CallSchedule` and a pointer into it.
    Every :meth:`step` is ONE network call that advances *every* live row
    by one entry of its own schedule — rows sit at different diffusion
    times (the denoiser takes per-row ``t_norm``) and draw their noise
    from their own per-request key stream, so each request's trajectory
    is bit-for-bit the solo batch-of-one run under the same key stream.
    Free slots pass through untouched (parked at a sentinel time outside
    every schedule — T+1 on a discrete grid, 2.0 in continuous time —
    and additionally gated out inside every row step), and a slot is
    re-admittable the moment its request completes — mid-flight
    admission costs nothing but an ``.at[row].set``.

    ``prefix_len > 0`` makes the runner conditional: it keeps a
    ``(rows, prefix_len)`` prefix buffer fed to the denoiser as
    ``cond={"prefix_tokens": ...}`` and every admission must supply a
    prefix of exactly that length (the continuous scheduler groups
    conditional traffic by (method, prefix length), so rows are never
    padded and per-row solo parity is preserved).  Free rows hold the
    noise pad token.

    Completed rows are harvested *inside* :meth:`step` (returned as
    ``{row: tokens}``) before any later call can touch the buffer, so
    results are exactly-once by construction.
    """

    def __init__(self, engine: GenerationEngine, method: str, rows: int,
                 N: int, prefix_len: int = 0):
        spec = engine.check_method(method)
        if spec.stepwise_step is None:
            raise ValueError(
                f"{method} has no stepwise step; stepwise-capable methods: "
                f"{', '.join(n for n in registry.names() if registry.get(n).stepwise_step)}")
        self.engine = engine
        self.method = method
        self.spec = spec
        self.rt = engine.runtime()
        self.rows = rows
        self.N = N
        self.prefix_len = prefix_len
        if spec.continuous_time:
            # timestamps live in (0, 1]; 2.0 is past every schedule
            self._t_dtype, self._t_free = np.float32, 2.0
        else:
            self._t_dtype, self._t_free = np.int32, self.rt.dist.T + 1
        self.x = jnp.zeros((rows, N), jnp.int32)
        self.revealed = jnp.zeros((rows, N), bool)
        self.tau = jnp.zeros((rows, N), jnp.dtype(self._t_dtype))
        self.prefix = (jnp.full((rows, prefix_len), engine.noise.pad_id,
                                jnp.int32) if prefix_len else None)
        self._plans: list[CallSchedule | None] = [None] * rows
        self._ptr = [0] * rows
        self.calls = 0                          # batched network calls

    def free_rows(self) -> list[int]:
        return [i for i in range(self.rows) if self._plans[i] is None]

    def active_rows(self) -> list[int]:
        return [i for i in range(self.rows) if self._plans[i] is not None]

    def admit(self, row: int, plan: CallSchedule,
              prefix: np.ndarray | None = None) -> None:
        """Install a request's plan into a free slot (any step boundary)."""
        self.admit_many([(row, plan)],
                        None if prefix is None else [prefix])

    def admit_many(self, pairs: list[tuple[int, CallSchedule]],
                   prefixes: list[np.ndarray] | None = None) -> None:
        """Install several plans with ONE scatter per buffer — the per-op
        dispatch cost of ``.at[row].set`` dominates admission otherwise.

        Plans must carry (x0, step_keys); ``tau`` is additionally
        required for the tau-consuming methods (the DNDM family) and
        ignored by the schedule-driven baselines (``tau=None`` plans).
        ``prefixes`` (aligned with ``pairs``) is required iff the runner
        was built with ``prefix_len > 0``.
        """
        if not pairs:
            return
        if bool(prefixes) != bool(self.prefix_len):
            raise ValueError(
                "conditional runner needs one prefix per admission"
                if self.prefix_len else
                "unconditional runner cannot admit prefixes")
        for row, plan in pairs:
            if self._plans[row] is not None:
                raise ValueError(f"row {row} is occupied")
            if plan.x0 is None or plan.step_keys is None:
                raise ValueError("stepwise admission needs a full plan "
                                 "(x0, step_keys) — see samplers/stepwise")
        idx = jnp.asarray([row for row, _ in pairs], jnp.int32)
        x0 = np.stack([np.asarray(p.x0, np.int32).reshape(self.N)
                       for _, p in pairs])
        tau = np.stack([
            np.zeros(self.N, self._t_dtype) if p.tau is None
            else np.asarray(p.tau, self._t_dtype).reshape(self.N)
            for _, p in pairs])
        self.x = self.x.at[idx].set(jnp.asarray(x0))
        self.revealed = self.revealed.at[idx].set(False)
        self.tau = self.tau.at[idx].set(jnp.asarray(tau))
        if self.prefix_len:
            pre = np.stack([np.asarray(p, np.int32).reshape(self.prefix_len)
                            for p in prefixes])
            self.prefix = self.prefix.at[idx].set(jnp.asarray(pre))
        for row, plan in pairs:
            self._plans[row] = plan
            self._ptr[row] = 0

    def step(self) -> dict[int, np.ndarray]:
        """One batched network call; returns tokens of rows that finished.

        With telemetry on, every call is an ``engine.stepwise`` span
        whose ``request_ids`` attribute lists the trace identity of each
        row the call advanced (comma-joined) — the per-call backbone of
        ``obs.timeline(request_id)``.
        """
        active = self.active_rows()
        if not active:
            return {}
        t_row = np.full((self.rows,), self._t_free, self._t_dtype)
        keys = np.zeros((self.rows, 2), np.uint32)
        for i in active:
            plan = self._plans[i]
            t_row[i] = plan.times[self._ptr[i]]
            keys[i] = plan.step_keys[self._ptr[i]]
        cond = (None if self.prefix is None
                else {"prefix_tokens": self.prefix})
        rids = (",".join(p.request_id for i in active
                         if (p := self._plans[i]).request_id is not None)
                if obs.enabled() else "")
        with obs.span("engine.stepwise", method=self.method,
                      call=self.calls, rows=len(active),
                      request_ids=rids):
            state = self.spec.stepwise_step(
                {"x": self.x, "revealed": self.revealed},
                self.tau, jnp.asarray(t_row), jnp.asarray(keys), cond,
                self.rt)
            self.x, self.revealed = state["x"], state["revealed"]
        self.calls += 1
        if obs.enabled():
            obs.counter("engine.stepwise_calls").inc(method=self.method)
        done: dict[int, np.ndarray] = {}
        finished = [i for i in active
                    if self._ptr[i] + 1 == len(self._plans[i].times)]
        if finished:
            # one transfer of the whole buffer: cheaper than per-row
            # device slices, and the sync point keeps the dispatch queue
            # shallow on CPU
            host_x = np.asarray(jax.device_get(self.x))
        for i in active:
            self._ptr[i] += 1
            if self._ptr[i] == len(self._plans[i].times):
                done[i] = host_x[i].copy()
                self._plans[i] = None
        return done
