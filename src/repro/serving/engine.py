"""Generation engine: one object that binds (model params, sampler family)
and serves batched requests.

The engine exposes every sampler in the repo behind one call so the
benchmarks and the serving launcher compare apples-to-apples:

  method in {"dndm", "dndm2", "dndm_topk", "dndm_static",
             "dndm_topk_static", "dndm_c", "dndm_c_topk",
             "d3pm", "rdm", "rdm_k", "mask_predict"}

For conditional requests, ``cond={"prefix_tokens": src}``: the model
wrapper feeds [src | x_t] with bidirectional attention and returns target
logits, so samplers stay prefix-agnostic.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core import schedules as sched_lib
from repro.core import transition as trans_lib
from repro.core.noise import NoiseDist
from repro.core.samplers import (SamplerConfig, d3pm, dndm, dndm_continuous,
                                 dndm_topk, mask_predict, rdm)
from repro.models.model import Model


@dataclasses.dataclass
class EngineConfig:
    method: str = "dndm"
    steps: int = 50                   # T for discrete methods / MP iters
    schedule: str = "linear"
    noise_kind: str = "absorbing"
    beta: tuple[float, float] | None = None   # Beta approx of D_tau
    nfe_budget: int = 0               # static variants
    x0_mode: str = "sample"
    temperature: float = 1.0
    order: str = "iid"                # iid | l2r | r2l
    shared_tau: bool = True           # one tau-set per batch (paper NFE)


class GenerationEngine:
    def __init__(self, model: Model, params, engine_cfg: EngineConfig):
        self.model = model
        self.params = params
        self.cfg = engine_cfg
        v = model.cfg.vocab_size
        if engine_cfg.noise_kind == "absorbing":
            from repro.core.noise import absorbing
            self.noise: NoiseDist = absorbing(v)
        else:
            from repro.core.noise import multinomial
            self.noise = multinomial(v)
        self.schedule = sched_lib.get(engine_cfg.schedule, engine_cfg.steps)
        if engine_cfg.beta:
            a, b = engine_cfg.beta
            self.dist = trans_lib.beta_approx(engine_cfg.steps, a, b)
            self.cdist = trans_lib.beta_continuous(a, b)
        else:
            self.dist = trans_lib.from_schedule(self.schedule)
            self.cdist = trans_lib.beta_continuous(17, 4)
        self.denoise_fn = model.denoise_fn(params)
        self._jit_cache: dict = {}

    # scan-based samplers have a statically known NFE, so the whole
    # sampler is jitted once per (batch, N) and reused across requests —
    # timing then measures execution, not retracing.
    def _scan_sampler(self, batch: int, N: int):
        c = self.cfg
        scfg = SamplerConfig(x0_mode=c.x0_mode, temperature=c.temperature)
        fn = self.denoise_fn
        m = c.method
        budget = c.nfe_budget or max(N // 2, 1)

        def call(key, cond):
            if m == "dndm_static":
                return dndm.sample_static(
                    key, fn, self.noise, self.dist, batch, N, budget,
                    cond=cond, cfg=scfg, order=c.order,
                    shared_tau=c.shared_tau).tokens
            if m == "dndm_topk_static":
                return dndm_topk.sample_static(
                    key, fn, self.noise, self.dist, batch, N, budget,
                    cond=cond, cfg=scfg, order=c.order,
                    shared_tau=c.shared_tau).tokens
            if m in ("dndm_c", "dndm_c_topk"):
                return dndm_continuous.sample(
                    key, fn, self.noise, self.cdist, batch, N, cond=cond,
                    cfg=scfg, topk=(m == "dndm_c_topk"), order=c.order,
                    shared_tau=c.shared_tau).tokens
            if m == "d3pm":
                return d3pm.sample(key, fn, self.noise, self.schedule,
                                   batch, N, cond=cond, cfg=scfg).tokens
            if m in ("rdm", "rdm_k"):
                return rdm.sample(key, fn, self.noise, self.schedule,
                                  batch, N, cond=cond, cfg=scfg,
                                  topk=(m == "rdm_k")).tokens
            if m == "mask_predict":
                return mask_predict.sample(key, fn, self.noise, c.steps,
                                           batch, N, cond=cond,
                                           cfg=scfg).tokens
            raise KeyError(m)

        nfe = {"dndm_static": budget, "dndm_topk_static": budget,
               "dndm_c": N, "dndm_c_topk": N, "d3pm": c.steps,
               "rdm": c.steps, "rdm_k": c.steps,
               "mask_predict": c.steps}[m]
        return jax.jit(call), nfe

    def generate(self, key, batch: int, N: int, cond: dict | None = None):
        """Returns (SamplerOutput, wall_seconds)."""
        c = self.cfg
        scfg = SamplerConfig(x0_mode=c.x0_mode, temperature=c.temperature)
        fn = self.denoise_fn
        t0 = time.time()
        m = c.method
        if m in ("dndm", "dndm2"):
            out = dndm.sample(key, fn, self.noise, self.dist, batch, N,
                              cond=cond, cfg=scfg,
                              version=(2 if m == "dndm2" else 1),
                              order=c.order, shared_tau=c.shared_tau)
        elif m == "dndm_topk":
            out = dndm_topk.sample(key, fn, self.noise, self.dist, batch,
                                   N, cond=cond, cfg=scfg, order=c.order,
                                   shared_tau=c.shared_tau)
        else:
            ck = (m, batch, N)
            if ck not in self._jit_cache:
                self._jit_cache[ck] = self._scan_sampler(batch, N)
            call, nfe = self._jit_cache[ck]
            tokens = call(key, cond)
            from repro.core.samplers.base import SamplerOutput
            out = SamplerOutput(tokens=tokens, nfe=nfe, aux={})
        jax.block_until_ready(out.tokens)
        return out, time.time() - t0
