"""Request scheduler: batches async generation requests.

Requests (each: target length + optional source prefix + optional sampler
method) are grouped into fixed-shape batches so the jitted samplers are
reused across requests — the serving-throughput path of deliverable (b).
The batch dimension is padded up to a power-of-two bucket (capped at
``max_batch``) before hitting the engine, so queues of different sizes
within a bucket share one compiled sampler instead of retracing per
distinct queue length; results are sliced back per request.  Methods are
validated against the sampler registry; requests naming different
methods are batched separately so each batch hits one compiled sampler.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.serving.engine import GenerationEngine


@dataclasses.dataclass
class Request:
    rid: int
    length: int
    prefix: np.ndarray | None = None        # (P,) source tokens
    method: str | None = None               # resolved at submit time
    result: np.ndarray | None = None
    nfe: int = 0
    wall: float = 0.0                       # amortized share of batch_wall
    batch_wall: float = 0.0                 # wall-clock of the whole batch
    batch_size: int = 0                     # requests served in that batch


class BatchScheduler:
    """Greedy fixed-bucket batching, grouped by sampler method."""

    def __init__(self, engine: GenerationEngine, max_batch: int = 8,
                 bucket_len: int = 64, seed: int = 0):
        self.engine = engine
        self.max_batch = max_batch
        self.bucket_len = bucket_len
        self.queue: list[Request] = []
        self.done: dict[int, Request] = {}
        self._rid = 0
        self._key = jax.random.PRNGKey(seed)

    def submit(self, length: int, prefix: np.ndarray | None = None,
               method: str | None = None) -> int:
        # normalize to a concrete method so explicit-default and default
        # requests land in the same batch, and fail fast (unknown name /
        # incompatible noise) — once a batch is popped in run() there is
        # no requeue path for it
        method = method or self.engine.cfg.method
        self.engine.check_method(method)
        self._rid += 1
        self.queue.append(Request(self._rid, length, prefix, method))
        return self._rid

    def batch_bucket(self, n: int) -> int:
        """Compiled batch size serving a group of ``n`` requests: the next
        power of two, capped at ``max_batch`` — a handful of (batch, N)
        shapes instead of one jit-cache entry per distinct queue size."""
        b = 1
        while b < n:
            b *= 2
        return min(b, self.max_batch)

    def _bucket(self) -> list[Request]:
        """Up to max_batch requests sharing the head request's method."""
        m0 = self.queue[0].method
        take: list[Request] = []
        rest: list[Request] = []
        for r in self.queue:
            if len(take) < self.max_batch and r.method == m0:
                take.append(r)
            else:
                rest.append(r)
        self.queue = rest
        return take

    def run(self) -> dict[int, Request]:
        """Drain the queue; returns completed requests by id.

        Each request records the *amortized* per-request wall share
        (``wall = batch_wall / batch_size``) plus the batch totals
        (``batch_wall``, ``batch_size``) — the batch runs once for all
        its members, so attributing the full wall-clock to every request
        would overcount serving cost by the batch size.
        """
        while self.queue:
            if obs.enabled():
                obs.gauge("scheduler.queue_depth").set(len(self.queue))
            batch = self._bucket()
            # pad the batch dim to the compiled bucket; padded rows are
            # generated (wasted work bounded by 2x) and sliced off below
            B = self.batch_bucket(len(batch))
            N = self.bucket_len
            m = batch[0].method
            cond = None
            if batch[0].prefix is not None:
                P = max(len(r.prefix) for r in batch)
                pre = np.zeros((B, P), np.int32)
                for i, r in enumerate(batch):
                    pre[i, P - len(r.prefix):] = r.prefix
                cond = {"prefix_tokens": jnp.asarray(pre)}
            self._key, k = jax.random.split(self._key)
            with obs.span("scheduler.batch", method=m, requests=len(batch),
                          bucket=B) as sp:
                out, wall = self.engine.generate(k, B, N, cond=cond,
                                                 method=m)
                if obs.enabled():
                    obs.counter("scheduler.batches").inc(method=m)
                    obs.counter("scheduler.requests").inc(len(batch),
                                                          method=m)
                    obs.counter("scheduler.padded_rows").inc(B - len(batch),
                                                             method=m)
                    obs.histogram("scheduler.occupancy").observe(
                        len(batch) / B, method=m)
                    obs.histogram("scheduler.batch_wall_seconds").observe(
                        wall, method=m)
                    sp.set(wall_s=wall, padded_rows=B - len(batch),
                           occupancy=len(batch) / B)
            toks = np.asarray(jax.device_get(out.tokens))
            share = wall / len(batch)
            for i, r in enumerate(batch):
                r.result = toks[i, : r.length]
                r.nfe = out.nfe
                r.wall = share
                r.batch_wall = wall
                r.batch_size = len(batch)
                self.done[r.rid] = r
        return self.done
