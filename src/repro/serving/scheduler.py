"""Request schedulers: drain-mode batching and continuous NFE-aware batching.

Two schedulers share the :class:`Request` record and the engine:

* :class:`BatchScheduler` — drain mode: requests are grouped by method
  into fixed-shape power-of-two buckets and each batch runs a whole
  sampler trajectory before the next batch starts.  Simple, but a
  request arriving one step after a batch launches waits out the whole
  batch, and with independent per-request tau sets the batch walks the
  *union* of every row's transition times — rows pay NFE for steps where
  they do not transition.
* :class:`ContinuousScheduler` — continuous mode: ``submit()`` samples
  the request's predetermined call schedule (``engine.plan_request``, the
  DNDM structural property as an API), and a rolling
  :class:`~repro.serving.engine.StepwiseRunner` batch admits requests at
  any step boundary into free rows.  Every batched call advances each
  live row by one entry of *its own* schedule, so no row ever pays for a
  step where it has no transition — per-request NFE stays at the solo
  ``|unique tau|`` while the batch stays full.

Methods are validated against the sampler registry at submit time;
requests naming different methods are batched separately so each batch
hits one compiled sampler.
"""
from __future__ import annotations

import dataclasses
import itertools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.obs import slo as slo_lib
from repro.serving.engine import GenerationEngine, StepwiseRunner

# process-wide request-id mint: ids stay unique across scheduler
# instances so one trace file can hold several schedulers' requests
_next_request_id = itertools.count(1).__next__


def mint_request_id() -> str:
    return f"req-{_next_request_id():06d}"


@dataclasses.dataclass
class Request:
    rid: int
    length: int
    prefix: np.ndarray | None = None        # (P,) source tokens
    method: str | None = None               # resolved at submit time
    result: np.ndarray | None = None
    nfe: int = 0
    wall: float = 0.0                       # amortized share of batch_wall
    batch_wall: float = 0.0                 # wall-clock of the whole batch
    batch_size: int = 0                     # requests served in that batch
    # lifecycle timestamps (time.time()): queue latency = t_admit -
    # t_submit, service time = t_done - t_admit
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_done: float = 0.0
    # continuous mode: the per-request key + predetermined call schedule
    # (set at submit) — replaying engine.generate(key, 1, N, method=...)
    # solo reproduces this request's tokens
    key: jax.Array | None = None
    plan: object | None = None
    steps_executed: int = 0
    steps_skipped: int = 0
    # trace identity, minted at submit(): every span/event this request
    # touches carries it, so obs.timeline(request_id) reconstructs the
    # full submit -> admission -> per-call -> completion history
    request_id: str = ""


class BatchScheduler:
    """Greedy fixed-bucket batching, grouped by sampler method."""

    def __init__(self, engine: GenerationEngine, max_batch: int = 8,
                 bucket_len: int = 64, seed: int = 0):
        self.engine = engine
        self.max_batch = max_batch
        self.bucket_len = bucket_len
        self.queue: list[Request] = []
        self.done: dict[int, Request] = {}
        self._rid = 0
        self._key = jax.random.PRNGKey(seed)

    def submit(self, length: int, prefix: np.ndarray | None = None,
               method: str | None = None) -> int:
        # normalize to a concrete method so explicit-default and default
        # requests land in the same batch, and fail fast (unknown name /
        # incompatible noise) — once a batch is popped in run() there is
        # no requeue path for it
        method = method or self.engine.cfg.method
        self.engine.check_method(method)
        self._rid += 1
        req = Request(self._rid, length, prefix, method)
        req.request_id = mint_request_id()
        req.t_submit = time.time()
        if obs.enabled():
            obs.event("scheduler.submit", request_id=req.request_id,
                      method=method, length=length, mode="drain")
        self.queue.append(req)
        return self._rid

    def batch_bucket(self, n: int) -> int:
        """Compiled batch size serving a group of ``n`` requests: the next
        power of two, capped at ``max_batch`` — a handful of (batch, N)
        shapes instead of one jit-cache entry per distinct queue size."""
        b = 1
        while b < n:
            b *= 2
        return min(b, self.max_batch)

    def _buckets(self) -> list[list[Request]]:
        """Split the queue into per-method FIFO batches of up to
        ``max_batch``, one grouping pass over the queue (methods keep
        first-arrival order).  Replaces the per-pop whole-queue rescan
        that made a mixed-method drain O(n^2)."""
        order: list[str] = []
        groups: dict[str, list[Request]] = {}
        for r in self.queue:
            if r.method not in groups:
                groups[r.method] = []
                order.append(r.method)
            groups[r.method].append(r)
        self.queue = []
        return [groups[m][i:i + self.max_batch] for m in order
                for i in range(0, len(groups[m]), self.max_batch)]

    def run(self) -> dict[int, Request]:
        """Drain the queue; returns completed requests by id.

        Each request records the *amortized* per-request wall share
        (``wall = batch_wall / batch_size``) plus the batch totals
        (``batch_wall``, ``batch_size``) — the batch runs once for all
        its members, so attributing the full wall-clock to every request
        would overcount serving cost by the batch size.
        """
        pending = len(self.queue)
        for batch in self._buckets():
            if obs.enabled():
                obs.gauge("scheduler.queue_depth").set(pending)
            pending -= len(batch)
            # pad the batch dim to the compiled bucket; padded rows are
            # generated (wasted work bounded by 2x) and sliced off below
            B = self.batch_bucket(len(batch))
            N = self.bucket_len
            m = batch[0].method
            cond = None
            if batch[0].prefix is not None:
                # left-pad short prefixes with the noise pad token ([MASK]
                # for absorbing) — padding with 0, a real vocab token,
                # would condition the row on spurious content.  A row's
                # reference run is therefore solo generation with the
                # same pad-extended prefix.
                P = max(len(r.prefix) for r in batch)
                pre = np.full((B, P), self.engine.noise.pad_id, np.int32)
                for i, r in enumerate(batch):
                    pre[i, P - len(r.prefix):] = r.prefix
                cond = {"prefix_tokens": jnp.asarray(pre)}
            self._key, k = jax.random.split(self._key)
            t_admit = time.time()
            rids = ",".join(r.request_id for r in batch)
            with obs.span("scheduler.batch", method=m, requests=len(batch),
                          bucket=B, request_ids=rids) as sp:
                if obs.enabled():
                    for r in batch:
                        obs.event("scheduler.admit",
                                  request_id=r.request_id, method=m,
                                  mode="drain",
                                  queue_s=t_admit - r.t_submit)
                out, wall = self.engine.generate(k, B, N, cond=cond,
                                                 method=m)
                if obs.enabled():
                    obs.counter("scheduler.batches").inc(method=m)
                    obs.counter("scheduler.requests").inc(len(batch),
                                                          method=m)
                    obs.counter("scheduler.padded_rows").inc(B - len(batch),
                                                             method=m)
                    obs.histogram("scheduler.occupancy").observe(
                        len(batch) / B, method=m)
                    obs.histogram("scheduler.batch_wall_seconds").observe(
                        wall, method=m)
                    sp.set(wall_s=wall, padded_rows=B - len(batch),
                           occupancy=len(batch) / B)
            toks = np.asarray(jax.device_get(out.tokens))
            share = wall / len(batch)
            t_done = time.time()
            for i, r in enumerate(batch):
                r.result = toks[i, : r.length]
                r.nfe = out.nfe
                r.wall = share
                r.batch_wall = wall
                r.batch_size = len(batch)
                r.t_admit = t_admit
                r.t_done = t_done
                if obs.enabled():
                    obs.histogram("scheduler.queue_latency_seconds").observe(
                        t_admit - r.t_submit, mode="drain")
                    obs.histogram("scheduler.service_seconds").observe(
                        t_done - t_admit, mode="drain")
                    obs.event("scheduler.complete",
                              request_id=r.request_id, method=r.method,
                              mode="drain", nfe=r.nfe,
                              service_s=t_done - t_admit)
                    slo_lib.observe_request(
                        r.method, latency_s=t_done - t_admit,
                        queue_s=t_admit - r.t_submit, nfe=r.nfe)
                self.done[r.rid] = r
        return self.done


class ContinuousScheduler:
    """Continuous NFE-aware batching over a rolling stepwise batch.

    ``submit()`` samples the request's predetermined call schedule
    immediately (``engine.plan_request`` under a per-request key), so the
    scheduler knows every network call the request will make before it is
    admitted.  A :class:`~repro.serving.engine.StepwiseRunner` holds up
    to ``max_batch`` in-flight rows; :meth:`pump` admits queued requests
    into free rows at the current step boundary (no drain barrier) and
    issues one batched network call advancing every live row along its
    own schedule.  Steps outside a request's schedule are never executed
    for it — per-request ``steps_skipped`` (= T - |unique tau|) counts
    the no-op grid steps the predetermined schedule proved unnecessary,
    and the batch-level call count is ``max`` over the cohort's schedule
    lengths instead of drain mode's ``|union|``.

    Per-request results are bit-for-bit the solo
    ``engine.generate(request.key, 1, N, method=...)`` run whenever the
    denoiser is batch-shape-invariant, and exactly reproducible from
    ``request.key`` regardless (same tau set, same per-step key stream;
    see ``samplers/stepwise.py`` for the parity contract).

    Requests are grouped by (method, prefix length) — every registered
    method has a stepwise step, and conditional (prefix) requests get a
    conditional runner per exact prefix length, so prefixes are never
    padded inside a rolling batch and the solo-parity contract holds for
    them too.  Groups with work are served **round-robin** (one pump
    each, in first-arrival order of the group): a steady stream of one
    method can never starve queued requests of another — a group with
    work waits at most ``#groups-with-work - 1`` pumps for its next
    batched call.
    """

    def __init__(self, engine: GenerationEngine, max_batch: int = 8,
                 bucket_len: int = 64, seed: int = 0):
        self.engine = engine
        self.max_batch = max_batch
        self.bucket_len = bucket_len
        self.queue: list[Request] = []
        self.done: dict[int, Request] = {}
        self._rid = 0
        self._key = jax.random.PRNGKey(seed)
        # group = (method, prefix_len); 0 = unconditional
        self._runners: dict[tuple, StepwiseRunner] = {}
        self._rotation: list[tuple] = []    # groups in first-seen order
        self._rr = 0                        # round-robin cursor
        self._row_req: dict[tuple, Request] = {}  # (group, row) -> request
        self.total_calls = 0        # aggregate NFE: batched network calls

    def submit(self, length: int, prefix: np.ndarray | None = None,
               method: str | None = None) -> int:
        """Enqueue a request; its call schedule is sampled *now*."""
        if length > self.bucket_len:
            raise ValueError(f"length {length} > bucket_len "
                             f"{self.bucket_len}")
        method = method or self.engine.cfg.method
        spec = self.engine.check_method(method)
        if spec.stepwise_step is None:
            raise ValueError(
                f"{method} does not support continuous batching "
                "(no stepwise_step); submit it to BatchScheduler instead")
        self._rid += 1
        if prefix is not None:
            prefix = np.asarray(prefix, np.int32).reshape(-1)
        r = Request(self._rid, length, prefix, method)
        r.request_id = mint_request_id()
        r.key = jax.random.fold_in(self._key, self._rid)
        # stamp the trace identity onto the plan: the StepwiseRunner
        # reads it back to label every batched call this request rides
        r.plan = dataclasses.replace(
            self.engine.plan_request(r.key, self.bucket_len, method),
            request_id=r.request_id)
        r.t_submit = time.time()
        if obs.enabled():
            obs.event("scheduler.submit", request_id=r.request_id,
                      method=method, length=length, mode="continuous",
                      planned_nfe=r.plan.nfe)
        self.queue.append(r)
        return self._rid

    @staticmethod
    def _group(r: Request) -> tuple:
        return (r.method, 0 if r.prefix is None else len(r.prefix))

    def _runner(self, group: tuple) -> StepwiseRunner:
        if group not in self._runners:
            method, prefix_len = group
            self._runners[group] = self.engine.stepwise(
                self.max_batch, self.bucket_len, method,
                prefix_len=prefix_len)
        return self._runners[group]

    def _admit(self, group: tuple) -> None:
        """Move queued requests of ``group`` into its free rows."""
        runner = self._runner(group)
        free = runner.free_rows()
        if not free:
            return
        midflight = bool(runner.active_rows())
        take: list[Request] = []
        rest: list[Request] = []
        for r in self.queue:        # one pass, FIFO within the group
            if self._group(r) == group and len(take) < len(free):
                take.append(r)
            else:
                rest.append(r)
        self.queue = rest
        placed = list(zip(free, take))
        runner.admit_many(
            [(row, r.plan) for row, r in placed],
            [r.prefix for _, r in placed] if group[1] else None)
        t_admit = time.time()
        for row, r in placed:
            self._row_req[(group, row)] = r
            r.t_admit = t_admit
            if obs.enabled():
                obs.histogram("scheduler.queue_latency_seconds").observe(
                    r.t_admit - r.t_submit, mode="continuous")
                obs.event("scheduler.admit", request_id=r.request_id,
                          method=r.method, mode="continuous", row=row,
                          midflight=midflight,
                          queue_s=r.t_admit - r.t_submit)
                if midflight:
                    obs.counter("scheduler.admissions_midflight").inc(
                        method=r.method)

    def _next_group(self) -> tuple | None:
        """The next group with work, round-robin from the cursor.

        Work = live rows in the group's runner or queued requests of the
        group.  New groups join the rotation in first-arrival order; the
        cursor only ever advances one served group at a time, so no group
        with work is passed over twice before every other one is served
        — the fairness bound a steady single-method stream used to
        violate by pinning the old ``self._current`` forever.
        """
        for r in self.queue:
            g = self._group(r)
            if g not in self._rotation:
                self._rotation.append(g)
        n = len(self._rotation)
        for off in range(n):
            g = self._rotation[(self._rr + off) % n]
            runner = self._runners.get(g)
            if ((runner is not None and runner.active_rows())
                    or any(self._group(r) == g for r in self.queue)):
                self._rr = (self._rr + off + 1) % n
                return g
        return None

    def pump(self) -> bool:
        """Serve ONE group: admit what fits, issue one batched call.

        Returns True while work remains (queued or in flight).  Drive it
        from a serving loop interleaved with ``submit()`` calls; ``run()``
        below pumps to completion for synchronous use.
        """
        group = self._next_group()
        if group is None:
            return False
        with obs.span("scheduler.pump", method=group[0],
                      prefix_len=group[1]) as sp:
            self._admit(group)
            runner = self._runner(group)
            if obs.enabled():
                obs.gauge("scheduler.queue_depth").set(len(self.queue))
                obs.histogram("scheduler.occupancy").observe(
                    len(runner.active_rows()) / runner.rows,
                    method=group[0])
                sp.set(queue_depth=len(self.queue),
                       live_rows=len(runner.active_rows()))
            finished = runner.step()
            self.total_calls += 1
            t_done = time.time()
            for row, toks in finished.items():
                r = self._row_req.pop((group, row))
                r.result = toks[: r.length]
                r.nfe = r.plan.nfe
                r.steps_executed = r.plan.steps_executed
                r.steps_skipped = r.plan.steps_skipped
                r.t_done = t_done
                if obs.enabled():
                    obs.counter("scheduler.steps_skipped").inc(
                        r.steps_skipped, method=r.method)
                    obs.counter("scheduler.requests").inc(method=r.method)
                    obs.histogram("scheduler.service_seconds").observe(
                        t_done - r.t_admit, mode="continuous")
                    obs.event("scheduler.complete",
                              request_id=r.request_id, method=r.method,
                              mode="continuous", nfe=r.nfe,
                              steps_skipped=r.steps_skipped,
                              service_s=t_done - r.t_admit)
                    slo_lib.observe_request(
                        r.method, latency_s=t_done - r.t_admit,
                        queue_s=r.t_admit - r.t_submit, nfe=r.nfe)
                self.done[r.rid] = r
        return bool(self.queue or self._row_req)

    def run(self) -> dict[int, Request]:
        """Pump to completion; returns completed requests by id."""
        while self.pump():
            pass
        return self.done
