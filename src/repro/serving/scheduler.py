"""Request scheduler: batches async generation requests.

Requests (each: target length + optional source prefix) are grouped into
fixed-shape batches (pad to the engine's compiled (batch, N) buckets) so
the jitted samplers are reused across requests — the serving-throughput
path of deliverable (b).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.engine import GenerationEngine


@dataclasses.dataclass
class Request:
    rid: int
    length: int
    prefix: np.ndarray | None = None        # (P,) source tokens
    result: np.ndarray | None = None
    nfe: int = 0
    wall: float = 0.0


class BatchScheduler:
    """Greedy fixed-bucket batching."""

    def __init__(self, engine: GenerationEngine, max_batch: int = 8,
                 bucket_len: int = 64, seed: int = 0):
        self.engine = engine
        self.max_batch = max_batch
        self.bucket_len = bucket_len
        self.queue: list[Request] = []
        self.done: dict[int, Request] = {}
        self._rid = 0
        self._key = jax.random.PRNGKey(seed)

    def submit(self, length: int, prefix: np.ndarray | None = None) -> int:
        self._rid += 1
        self.queue.append(Request(self._rid, length, prefix))
        return self._rid

    def _bucket(self) -> list[Request]:
        take = self.queue[: self.max_batch]
        self.queue = self.queue[self.max_batch:]
        return take

    def run(self) -> dict[int, Request]:
        """Drain the queue; returns completed requests by id."""
        while self.queue:
            batch = self._bucket()
            B = len(batch)
            N = self.bucket_len
            cond = None
            if batch[0].prefix is not None:
                P = max(len(r.prefix) for r in batch)
                pre = np.zeros((B, P), np.int32)
                for i, r in enumerate(batch):
                    pre[i, P - len(r.prefix):] = r.prefix
                cond = {"prefix_tokens": jnp.asarray(pre)}
            self._key, k = jax.random.split(self._key)
            out, wall = self.engine.generate(k, B, N, cond=cond)
            toks = np.asarray(jax.device_get(out.tokens))
            for i, r in enumerate(batch):
                r.result = toks[i, : r.length]
                r.nfe = out.nfe
                r.wall = wall
                self.done[r.rid] = r
        return self.done
