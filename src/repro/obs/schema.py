"""Documented telemetry schemas + zero-dependency validator.

Three artifacts round-trip through this module:

**BENCH_*.json** (``benchmarks/run.py --json``, schema version 2)::

    {"schema": 2, "jax_backend": str, "quick": bool,
     "config": {"batch": int, "seq": int, "steps": int},
     "methods": {<name>: {"noise": str, "kind": "host"|"scan",
                          "wall_seconds": float, "compile_seconds": float,
                          "nfe": int, "tokens_per_second": float,
                          "us_per_nfe": float,
                          "metrics": {"jit_cache_hits": int,
                                      "jit_cache_misses": int}}},
     "telemetry": {"enabled": bool, "trace": str|null,
                   "metrics": {<metric>: {"type": str, "help": str,
                                          "series": [{"labels": {...},
                                                      "value": any}]}}}}

**BENCH_serving.json** (``benchmarks/run.py --serving``, schema
version 2, tagged ``"kind": "serving"``)::

    {"schema": 2, "kind": "serving", "jax_backend": str, "quick": bool,
     "config": {"max_batch": int, "seq": int, "steps": int,
                "requests": int, "method": str, "shared_tau": bool,
                "arrival_rate_rps": float},
     "modes": {"drain":      {"wall_seconds": float, "aggregate_nfe": int,
                              "throughput_rps": float,
                              "latency_p50_s": float,
                              "latency_p95_s": float,
                              "latency_p99_s": float},
               "continuous": {... same keys ..., "steps_skipped": int,
                              "admissions_midflight": int}},
     "comparison": {"nfe_ratio": float, "throughput_ratio": float,
                    "fewer_nfe": bool, "solo_parity": bool},
     "telemetry": {... as BENCH ...}}

**REPRO_TRACE JSON-lines** — one object per line, three kinds::

    {"kind": "span",    "name": str, "ts": float, "span_id": int,
     "parent_id": int|null, "dur_s": float, "attrs": {...}}
    {"kind": "event",   "name": str, "ts": float, "span_id": int,
     "parent_id": int|null, "attrs": {...}}
    {"kind": "metrics", "ts": float, "span_id": int, "parent_id": null,
     "attrs": {}, "metrics": {<metric>: {...}}}

CLI (the CI telemetry leg)::

    PYTHONPATH=src python -m repro.obs.schema BENCH_cpu.json trace.jsonl

validates the benchmark record, every trace line, and — because the
baseline sweep always includes the DNDM host samplers and a scheduler
drain — the acceptance-level content: an ``engine.generate`` span with
nfe/backend/jit-cache attrs, per-step ``sampler.step`` events carrying
|R_t| (``reveal``), and a ``metrics`` record with scheduler occupancy.
"""
from __future__ import annotations

import json
import sys
from typing import Iterable

BENCH_SCHEMA_VERSION = 2

_SPAN_KINDS = ("span", "event", "metrics")


class SchemaError(ValueError):
    pass


def _check(ok: bool, path: str, msg: str) -> None:
    if not ok:
        raise SchemaError(f"{path}: {msg}")


def _typed(obj: dict, path: str, key: str, types) -> object:
    _check(key in obj, path, f"missing key {key!r}")
    v = obj[key]
    _check(isinstance(v, types), path,
           f"{key!r} is {type(v).__name__}, want {types}")
    return v


def _number(obj, path, key, minimum=None):
    v = _typed(obj, path, key, (int, float))
    _check(not isinstance(v, bool), path, f"{key!r} is bool, want number")
    if minimum is not None:
        _check(v >= minimum, path, f"{key!r}={v} < {minimum}")
    return v


def validate_metrics_snapshot(snap: dict, path: str = "metrics") -> None:
    _check(isinstance(snap, dict), path, "snapshot must be an object")
    for name, inst in snap.items():
        p = f"{path}.{name}"
        _typed(inst, p, "type", str)
        _typed(inst, p, "help", str)
        series = _typed(inst, p, "series", list)
        for i, s in enumerate(series):
            sp = f"{p}.series[{i}]"
            _check(isinstance(s, dict), p, f"series[{i}] must be an object")
            _typed(s, sp, "labels", dict)
            _check("value" in s, sp, "missing 'value'")
            if inst["type"] == "histogram":
                # quantiles are first-class: every histogram series
                # carries sketch-backed p50/p95/p99 plus the serialized
                # sketch itself (repro.obs.sketch) for arbitrary q
                v = _typed(s, sp, "value", dict)
                for q in ("p50", "p95", "p99"):
                    _number(v, f"{sp}.value", q, minimum=0.0)
                sk = _typed(v, f"{sp}.value", "sketch", dict)
                _number(sk, f"{sp}.value.sketch", "alpha", minimum=0.0)
                _number(sk, f"{sp}.value.sketch", "count", minimum=0)
                _typed(sk, f"{sp}.value.sketch", "bins", dict)


def validate_bench(record: dict) -> None:
    """Raise :class:`SchemaError` unless ``record`` is a valid v2 bench."""
    p = "bench"
    _check(isinstance(record, dict), p, "record must be an object")
    _check(record.get("schema") == BENCH_SCHEMA_VERSION, p,
           f"schema={record.get('schema')!r}, want {BENCH_SCHEMA_VERSION}")
    _typed(record, p, "jax_backend", str)
    _typed(record, p, "quick", bool)
    cfg = _typed(record, p, "config", dict)
    for k in ("batch", "seq", "steps"):
        _number(cfg, f"{p}.config", k, minimum=1)
    methods = _typed(record, p, "methods", dict)
    _check(len(methods) > 0, p, "methods is empty")
    for m, rec in methods.items():
        mp = f"{p}.methods.{m}"
        _typed(rec, mp, "noise", str)
        kind = _typed(rec, mp, "kind", str)
        _check(kind in ("host", "scan"), mp, f"kind={kind!r}")
        _number(rec, mp, "wall_seconds", minimum=0.0)
        _number(rec, mp, "compile_seconds", minimum=0.0)
        _number(rec, mp, "nfe", minimum=0)
        _number(rec, mp, "tokens_per_second", minimum=0.0)
        _number(rec, mp, "us_per_nfe", minimum=0.0)
        met = _typed(rec, mp, "metrics", dict)
        _number(met, f"{mp}.metrics", "jit_cache_hits", minimum=0)
        _number(met, f"{mp}.metrics", "jit_cache_misses", minimum=0)
    tel = _typed(record, p, "telemetry", dict)
    _typed(tel, f"{p}.telemetry", "enabled", bool)
    _check("trace" in tel, f"{p}.telemetry", "missing 'trace'")
    _check(tel["trace"] is None or isinstance(tel["trace"], str),
           f"{p}.telemetry", "trace must be str or null")
    validate_metrics_snapshot(tel.get("metrics", {}),
                              f"{p}.telemetry.metrics")


_MODE_KEYS = ("wall_seconds", "throughput_rps", "latency_p50_s",
              "latency_p95_s", "latency_p99_s")


def validate_serving(record: dict) -> None:
    """Raise :class:`SchemaError` unless ``record`` is a valid serving
    benchmark artifact (``benchmarks/run.py --serving``)."""
    p = "serving"
    _check(isinstance(record, dict), p, "record must be an object")
    _check(record.get("schema") == BENCH_SCHEMA_VERSION, p,
           f"schema={record.get('schema')!r}, want {BENCH_SCHEMA_VERSION}")
    _check(record.get("kind") == "serving", p,
           f"kind={record.get('kind')!r}, want 'serving'")
    _typed(record, p, "jax_backend", str)
    _typed(record, p, "quick", bool)
    cfg = _typed(record, p, "config", dict)
    for k in ("max_batch", "seq", "steps", "requests"):
        _number(cfg, f"{p}.config", k, minimum=1)
    _typed(cfg, f"{p}.config", "method", str)
    _number(cfg, f"{p}.config", "arrival_rate_rps", minimum=0.0)
    modes = _typed(record, p, "modes", dict)
    for mode in ("drain", "continuous"):
        _check(mode in modes, f"{p}.modes", f"missing mode {mode!r}")
        mp = f"{p}.modes.{mode}"
        rec = modes[mode]
        _check(isinstance(rec, dict), mp, "mode record must be an object")
        for k in _MODE_KEYS:
            _number(rec, mp, k, minimum=0.0)
        _number(rec, mp, "aggregate_nfe", minimum=1)
    cp = f"{p}.comparison"
    cmp_rec = _typed(record, p, "comparison", dict)
    _number(cmp_rec, cp, "nfe_ratio", minimum=0.0)
    _number(cmp_rec, cp, "throughput_ratio", minimum=0.0)
    _typed(cmp_rec, cp, "fewer_nfe", bool)
    _typed(cmp_rec, cp, "solo_parity", bool)
    _number(modes["continuous"], f"{p}.modes.continuous", "steps_skipped",
            minimum=0)
    _number(modes["continuous"], f"{p}.modes.continuous",
            "admissions_midflight", minimum=0)
    tel = _typed(record, p, "telemetry", dict)
    _typed(tel, f"{p}.telemetry", "enabled", bool)
    validate_metrics_snapshot(tel.get("metrics", {}),
                              f"{p}.telemetry.metrics")


def validate_trace_lines(lines: Iterable[str]) -> list[dict]:
    """Structural check of a JSON-lines trace; returns parsed records."""
    out: list[dict] = []
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        p = f"trace:{i + 1}"
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            raise SchemaError(f"{p}: not JSON ({e})") from None
        _check(isinstance(rec, dict), p, "line must be an object")
        kind = _typed(rec, p, "kind", str)
        _check(kind in _SPAN_KINDS, p, f"kind={kind!r}")
        _number(rec, p, "ts", minimum=0.0)
        _number(rec, p, "span_id", minimum=1)
        _check("parent_id" in rec, p, "missing 'parent_id'")
        _check(rec["parent_id"] is None
               or isinstance(rec["parent_id"], int), p,
               "parent_id must be int or null")
        _typed(rec, p, "attrs", dict)
        if kind in ("span", "event"):
            _typed(rec, p, "name", str)
        if kind == "span":
            _number(rec, p, "dur_s", minimum=0.0)
        if kind == "metrics":
            validate_metrics_snapshot(_typed(rec, p, "metrics", dict), p)
        out.append(rec)
    return out


def validate_trace_content(records: list[dict]) -> None:
    """Acceptance-level content checks for a full DNDM benchmark trace."""
    p = "trace"
    gen = [r for r in records
           if r["kind"] == "span" and r["name"] == "engine.generate"]
    _check(len(gen) > 0, p, "no engine.generate span")
    _check(any({"nfe", "backend", "cache"} <= set(r["attrs"]) for r in gen),
           p, "no engine.generate span with nfe/backend/cache attrs")
    steps = [r for r in records
             if r["kind"] == "event" and r["name"] == "sampler.step"]
    _check(any("reveal" in r["attrs"] for r in steps),
           p, "no sampler.step event with a per-step reveal count (|R_t|)")
    mets = [r for r in records if r["kind"] == "metrics"]
    _check(len(mets) > 0, p, "no metrics record")
    final = mets[-1]["metrics"]
    for required in ("engine.jit_cache.misses", "scheduler.occupancy",
                     "decode.backend_calls"):
        _check(required in final, p,
               f"final metrics record lacks {required!r}")


def main(argv: list[str]) -> int:
    if not argv or len(argv) > 2:
        print("usage: python -m repro.obs.schema BENCH.json [trace.jsonl]",
              file=sys.stderr)
        return 2
    try:
        with open(argv[0]) as f:
            record = json.load(f)
        if record.get("kind") == "serving":
            validate_serving(record)
            print(f"ok: {argv[0]} valid serving record (schema "
                  f"{BENCH_SCHEMA_VERSION}, "
                  f"{len(record['modes'])} modes)")
        else:
            validate_bench(record)
            print(f"ok: {argv[0]} valid (schema {BENCH_SCHEMA_VERSION}, "
                  f"{len(record['methods'])} methods)")
        if len(argv) == 2:
            with open(argv[1]) as f:
                records = validate_trace_lines(f)
            validate_trace_content(records)
            spans = sum(r["kind"] == "span" for r in records)
            events = sum(r["kind"] == "event" for r in records)
            print(f"ok: {argv[1]} valid ({spans} spans, {events} events, "
                  f"{len(records)} records)")
    except (OSError, json.JSONDecodeError, SchemaError) as e:
        print(f"schema validation FAILED: {e}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
