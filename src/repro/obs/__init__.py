"""Runtime telemetry: metrics registry + trace spans + schema.

Disabled by default and near-free when disabled (one guard check per
instrumented call site).  Three ways to turn it on:

* ``REPRO_TRACE=path.jsonl``  — enable metrics *and* export every span /
  event / metrics record as JSON lines to ``path`` (schema in
  :mod:`repro.obs.schema`);
* ``REPRO_METRICS=1``         — enable the in-process metrics registry
  only (``obs.snapshot()`` / ``obs.summary()``);
* ``obs.enable()``            — programmatic, e.g. from tests.

``REPRO_JAX_PROFILE=dir`` additionally wraps every ``engine.generate``
in ``jax.profiler.trace(dir)`` for device-level TPU traces.

See the "Observability" section of ARCHITECTURE.md for the metric-name
table and which layer emits what.
"""
from __future__ import annotations

import os

from repro.obs import metrics, tracing
from repro.obs.metrics import (counter, disable, enable, enabled, gauge,
                               histogram, reset, snapshot, suppressed)
from repro.obs.tracing import (event, maybe_jax_profile, set_sink, span,
                               summary, write_metrics_record)

__all__ = [
    "counter", "gauge", "histogram", "snapshot", "reset",
    "enable", "disable", "enabled", "suppressed",
    "span", "event", "summary", "set_sink", "write_metrics_record",
    "maybe_jax_profile", "metrics", "tracing", "configure_from_env",
]


def configure_from_env() -> None:
    """Read REPRO_TRACE / REPRO_METRICS once; idempotent."""
    trace = os.environ.get("REPRO_TRACE", "").strip()
    if trace:
        enable()
        if tracing.sink_path() != trace:
            set_sink(trace)
    elif os.environ.get("REPRO_METRICS", "").strip() not in ("", "0"):
        enable()


configure_from_env()
