"""Runtime telemetry: metrics registry + trace spans + quantile sketches
+ live export + SLOs + schema.

Disabled by default and near-free when disabled (one guard check per
instrumented call site).  Ways to turn it on:

* ``REPRO_TRACE=path.jsonl``  — enable metrics *and* export every span /
  event / metrics record as JSON lines to ``path`` (buffered; schema in
  :mod:`repro.obs.schema`);
* ``REPRO_METRICS=1``         — enable the in-process metrics registry
  only (``obs.snapshot()`` / ``obs.summary()``);
* ``REPRO_METRICS_PORT=9099`` — enable metrics *and* serve them live:
  Prometheus text at ``/metrics``, JSON at ``/snapshot``
  (:mod:`repro.obs.exporter`);
* ``REPRO_SNAPSHOT=path.json`` (``REPRO_SNAPSHOT_INTERVAL=5``) — enable
  metrics and write the JSON snapshot to a file every interval, for
  headless runs nothing can scrape;
* ``REPRO_SLO=latency<0.25@0.99,nfe<64`` — declarative per-request
  budgets scored at request completion (:mod:`repro.obs.slo`);
* ``obs.enable()``            — programmatic, e.g. from tests.

``REPRO_JAX_PROFILE=dir`` additionally wraps every ``engine.generate``
in ``jax.profiler.trace(dir)`` for device-level TPU traces.

Every serving-path record carries the request id minted at
``submit()``; ``obs.timeline(request_id)`` (optionally with a trace-file
path) reconstructs one request's full submit → admission → per-call →
completion history.  See the "Observability" section of ARCHITECTURE.md
for the metric-name table and which layer emits what.
"""
from __future__ import annotations

import os

from repro.obs import exporter, metrics, sketch, slo, tracing
from repro.obs.metrics import (counter, disable, enable, enabled, gauge,
                               histogram, reset, snapshot, suppressed)
from repro.obs.tracing import (event, flush_sink, maybe_jax_profile,
                               set_sink, span, summary, timeline,
                               write_metrics_record)

__all__ = [
    "counter", "gauge", "histogram", "snapshot", "reset",
    "enable", "disable", "enabled", "suppressed",
    "span", "event", "summary", "set_sink", "flush_sink", "timeline",
    "write_metrics_record", "maybe_jax_profile",
    "metrics", "tracing", "sketch", "exporter", "slo",
    "configure_from_env",
]


def configure_from_env() -> None:
    """Read REPRO_TRACE / REPRO_METRICS / exporter / SLO env; idempotent."""
    trace = os.environ.get("REPRO_TRACE", "").strip()
    port = os.environ.get("REPRO_METRICS_PORT", "").strip()
    snap = os.environ.get("REPRO_SNAPSHOT", "").strip()
    if trace:
        enable()
        if tracing.sink_path() != trace:
            set_sink(trace)
    elif os.environ.get("REPRO_METRICS", "").strip() not in ("", "0"):
        enable()
    if port:
        enable()
        exporter.serve(int(port))
    if snap:
        enable()
        interval = float(
            os.environ.get("REPRO_SNAPSHOT_INTERVAL", "5") or 5)
        exporter.start_snapshot_writer(snap, interval)
    spec = os.environ.get("REPRO_SLO", "").strip()
    if spec and not slo.active():
        slo.configure(slo.parse(spec))


configure_from_env()
