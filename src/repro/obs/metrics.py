"""Process-wide metrics registry: counters, gauges, histograms — labeled.

Zero-dependency and **disabled by default**: every record method opens
with a single ``if not _ENABLED or _SUPPRESSED: return`` guard, so with
telemetry off the cost of an instrumented call site is one short-circuited
module-global read (no label-dict construction, no allocation, verified
by ``tests/test_obs.py::test_disabled_path_overhead``).  Enable with
:func:`enable` or by setting ``REPRO_METRICS=1`` / ``REPRO_TRACE=...``
in the environment (read once when ``repro.obs`` is imported); silence a
re-executed computation without flipping the global with
:func:`suppressed`.

Instruments are created lazily by name (``counter(name)`` is
get-or-create; name collisions across types raise) and accept arbitrary
keyword labels per record call::

    obs.counter("engine.nfe").inc(out.nfe, method="dndm")
    obs.histogram("engine.wall_seconds").observe(wall, method="dndm")

Semantics note for jitted code: a record call placed inside a
``jax.jit``-traced Python body executes at *trace* time — once per
compilation, not once per device execution.  The kernel padding gauges
and decode backend counters live in traced code deliberately: they
describe the compiled program (one value per trace), and are documented
as such in ARCHITECTURE.md.
"""
from __future__ import annotations

import contextlib
import copy
import threading

from repro.obs.sketch import DDSketch

_ENABLED = False
_SUPPRESSED = 0


def enabled() -> bool:
    return _ENABLED and not _SUPPRESSED


@contextlib.contextmanager
def suppressed():
    """Temporarily silence every instrument (and, via the shared
    ``enabled()`` gate, trace spans/events) without touching the global
    on/off state.  For work that re-executes an already-measured
    computation — e.g. the engine's untimed host-sampler warm-up run —
    where recording would double-count real serving metrics.  Reentrant;
    not thread-local (the repo's schedulers are single-threaded)."""
    global _SUPPRESSED
    _SUPPRESSED += 1
    try:
        yield
    finally:
        _SUPPRESSED -= 1


def enable() -> None:
    global _ENABLED
    _ENABLED = True


def disable() -> None:
    global _ENABLED
    _ENABLED = False


def _labels_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


class _Instrument:
    kind = "abstract"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.series: dict = {}       # labels-key -> value/stats

    def _snapshot_value(self, v):
        return v

    def snapshot(self) -> dict:
        return {"type": self.kind, "help": self.help,
                "series": [{"labels": dict(k),
                            "value": self._snapshot_value(v)}
                           for k, v in sorted(self.series.items())]}


class Counter(_Instrument):
    kind = "counter"

    def inc(self, value: float = 1, **labels) -> None:
        if not _ENABLED or _SUPPRESSED:
            return
        k = _labels_key(labels)
        with _lock:
            self.series[k] = self.series.get(k, 0) + value

    def value(self, **labels):
        return self.series.get(_labels_key(labels), 0)


class Gauge(_Instrument):
    kind = "gauge"

    def set(self, value, **labels) -> None:
        if not _ENABLED or _SUPPRESSED:
            return
        with _lock:
            self.series[_labels_key(labels)] = value

    def value(self, **labels):
        return self.series.get(_labels_key(labels))


# decade buckets: 100ns .. 100s covers step timings and reveal counts
_BUCKET_EDGES = tuple(10.0 ** e for e in range(-7, 3))

# pre-computed quantiles every histogram snapshot carries; arbitrary
# quantiles stay available via the serialized sketch
# (repro.obs.sketch.quantile_of_snapshot)
QUANTILES = (0.5, 0.95, 0.99)


class Histogram(_Instrument):
    """Decade-bucket histogram + DDSketch per series.

    Every series carries a fixed-memory relative-error quantile sketch
    (``sketch.DDSketch``, alpha = 1%) next to the coarse decade buckets,
    so p50/p95/p99 are first-class in snapshots, ``summary()`` and the
    Prometheus exporter — with documented ≤ 1% relative error instead of
    "somewhere in this decade".
    """

    kind = "histogram"

    def observe(self, value: float, **labels) -> None:
        if not _ENABLED or _SUPPRESSED:
            return
        k = _labels_key(labels)
        with _lock:
            s = self.series.get(k)
            if s is None:
                s = self.series[k] = {
                    "count": 0, "sum": 0.0, "min": value, "max": value,
                    "buckets": [0] * (len(_BUCKET_EDGES) + 1),
                    "sketch": DDSketch()}
            s["count"] += 1
            s["sum"] += value
            if value < s["min"]:
                s["min"] = value
            if value > s["max"]:
                s["max"] = value
            i = 0
            for edge in _BUCKET_EDGES:
                if value <= edge:
                    break
                i += 1
            s["buckets"][i] += 1
            s["sketch"].add(value)

    def value(self, **labels):
        return self.series.get(_labels_key(labels))

    def _snapshot_value(self, s: dict) -> dict:
        buckets = {}
        for i, c in enumerate(s["buckets"]):
            if c:
                le = (f"{_BUCKET_EDGES[i]:g}" if i < len(_BUCKET_EDGES)
                      else "inf")
                buckets[f"le_{le}"] = c
        sk: DDSketch = s["sketch"]
        out = {"count": s["count"], "sum": s["sum"], "min": s["min"],
               "max": s["max"],
               "mean": s["sum"] / s["count"] if s["count"] else 0.0,
               "buckets": buckets, "sketch": sk.to_dict()}
        for q in QUANTILES:
            out[f"p{int(q * 100)}"] = sk.quantile(q)
        return out


_lock = threading.RLock()
_REGISTRY: dict[str, _Instrument] = {}


def _get(cls, name: str, help: str) -> _Instrument:
    with _lock:
        inst = _REGISTRY.get(name)
        if inst is None:
            inst = _REGISTRY[name] = cls(name, help)
        elif not isinstance(inst, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{inst.kind}, not {cls.kind}")
        return inst


def counter(name: str, help: str = "") -> Counter:
    return _get(Counter, name, help)


def gauge(name: str, help: str = "") -> Gauge:
    return _get(Gauge, name, help)


def histogram(name: str, help: str = "") -> Histogram:
    return _get(Histogram, name, help)


def snapshot() -> dict:
    """JSON-able view of every instrument with at least one series.

    Taken under the registry lock that every record call also holds, so
    a concurrent reader (the ``/metrics`` exporter thread, the snapshot
    writer) never observes a torn series — e.g. a histogram whose
    ``count`` was bumped but whose ``sum``/sketch were not yet.  The
    returned structure is freshly built (histogram buckets and sketches
    are serialized copies), so callers can hold it across further
    recording without aliasing live state.
    """
    with _lock:
        return copy.deepcopy({name: inst.snapshot()
                              for name, inst in sorted(_REGISTRY.items())
                              if inst.series})


def reset() -> None:
    """Clear recorded values; registered instruments survive."""
    with _lock:
        for inst in _REGISTRY.values():
            inst.series.clear()
