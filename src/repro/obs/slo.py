"""Declarative serving SLOs: latency / NFE budgets with error-budget
burn accounting.

A :class:`Budget` says "metric X of a completed request must stay under
``limit`` for at least ``objective`` of requests" — e.g. p99-style
"99% of dndm requests complete within 250 ms", or "no request may spend
more than 64 network calls".  Both schedulers report every completed
request here (:func:`observe_request`); each matching budget counts the
request and, if it blew the limit, the breach:

* ``scheduler.slo_requests``  (counter; labels budget, method) — total
  requests a budget evaluated;
* ``scheduler.slo_breaches``  (counter; labels budget, method) —
  requests over the limit.

:func:`status` turns the counters into error-budget burn: a budget with
``objective = 0.99`` over ``n`` requests has an allowance of
``0.01 * n`` breaches; ``burn = breaches / allowance`` (> 1.0 means the
error budget is spent — the alerting threshold).  ``burn`` is exposed
per budget as the ``scheduler.slo_burn`` gauge every time it is read,
so the live ``/metrics`` endpoint carries it.

Configuration is data, not code::

    slo.configure([slo.Budget("latency", 0.25),                # all methods
                   slo.Budget("nfe", 64, objective=1.0),
                   slo.Budget("latency", 0.5, method="dndm_c")])

or the environment (read by ``obs.configure_from_env``)::

    REPRO_SLO="latency<0.25@0.99,nfe<64@1.0,dndm_c.latency<0.5"

entry grammar: ``[method.]metric<limit[@objective]`` — metric is one of
``latency`` (admission → completion seconds), ``queue`` (submit →
admission seconds) or ``nfe`` (network calls); objective defaults to
0.99; no method means every method.

With no budgets configured (the default) :func:`observe_request`
returns after one list check — the schedulers pay nothing.
"""
from __future__ import annotations

import dataclasses

from repro.obs import metrics as _metrics

METRICS = ("latency", "queue", "nfe")


@dataclasses.dataclass(frozen=True)
class Budget:
    metric: str                 # latency | queue | nfe
    limit: float                # per-request ceiling
    objective: float = 0.99     # target fraction of requests within limit
    method: str = "*"           # "*" = every method

    def __post_init__(self):
        if self.metric not in METRICS:
            raise ValueError(f"unknown SLO metric {self.metric!r}; "
                             f"choose from {METRICS}")
        if not 0.0 < self.objective <= 1.0:
            raise ValueError(f"objective must be in (0, 1], got "
                             f"{self.objective}")

    @property
    def name(self) -> str:
        scope = "" if self.method == "*" else f"{self.method}."
        return f"{scope}{self.metric}<{self.limit:g}"


_budgets: list[Budget] = []


def configure(budgets: list[Budget]) -> None:
    _budgets[:] = list(budgets)


def clear() -> None:
    _budgets.clear()


def budgets() -> tuple[Budget, ...]:
    return tuple(_budgets)


def active() -> bool:
    return bool(_budgets)


def parse(spec: str) -> list[Budget]:
    """``REPRO_SLO`` grammar -> budgets (see module docstring)."""
    out: list[Budget] = []
    for entry in spec.replace(";", ",").split(","):
        entry = entry.strip()
        if not entry:
            continue
        head, _, obj = entry.partition("@")
        metric, _, limit = head.partition("<")
        if not limit:
            raise ValueError(f"SLO entry {entry!r} lacks '<limit'")
        method, _, m = metric.rpartition(".")
        out.append(Budget(m.strip(), float(limit),
                          objective=float(obj) if obj else 0.99,
                          method=method.strip() or "*"))
    return out


def observe_request(method: str, *, latency_s: float | None = None,
                    queue_s: float | None = None,
                    nfe: float | None = None) -> None:
    """Score one completed request against every matching budget."""
    if not _budgets:
        return
    values = {"latency": latency_s, "queue": queue_s, "nfe": nfe}
    for b in _budgets:
        v = values[b.metric]
        if v is None or (b.method != "*" and b.method != method):
            continue
        _metrics.counter("scheduler.slo_requests",
                         "requests evaluated per SLO budget").inc(
            budget=b.name, method=method)
        if v > b.limit:
            _metrics.counter("scheduler.slo_breaches",
                             "requests over their SLO budget").inc(
                budget=b.name, method=method)


def status() -> dict:
    """Error-budget burn per budget: {name: {requests, breaches,
    allowance, burn, objective, limit}}.  Also refreshes the
    ``scheduler.slo_burn`` gauge so live scrapes carry it."""
    req = _metrics.counter("scheduler.slo_requests")
    brk = _metrics.counter("scheduler.slo_breaches")
    burn_g = _metrics.gauge("scheduler.slo_burn",
                            "error-budget burn (>1 = budget spent)")
    out: dict = {}
    for b in _budgets:
        with _metrics._lock:        # consistent read vs a recording pump
            n = sum(v for k, v in req.series.items()
                    if dict(k).get("budget") == b.name)
            breaches = sum(v for k, v in brk.series.items()
                           if dict(k).get("budget") == b.name)
        allowance = (1.0 - b.objective) * n
        burn = (breaches / allowance if allowance > 0
                else float(breaches > 0))
        burn_g.set(round(burn, 6), budget=b.name)
        out[b.name] = {"requests": int(n), "breaches": int(breaches),
                       "allowance": round(allowance, 3),
                       "burn": round(burn, 4),
                       "objective": b.objective, "limit": b.limit,
                       "metric": b.metric, "method": b.method}
    return out
