"""Bench-regression gate: compare two schema-2 benchmark artifacts.

::

    python -m repro.obs.regress BASE.json NEW.json [--wall-tol 0.5] ...

Both ``benchmarks/run.py --json`` (per-method bench) and ``--serving``
(Poisson drain-vs-continuous) artifacts are understood; BASE and NEW
must be the same kind.  Exit status: 0 = no regression, 1 = regression,
2 = usage / unreadable artifact — so CI can gate on it directly against
a committed baseline (``benchmarks/baselines/cpu_seed.json``).

Two classes of field, compared differently:

* **noise-aware relative thresholds** for anything timing-derived —
  wall seconds, throughput, latency quantiles, and (in serving mode)
  aggregate NFE, whose continuous-mode value counts *pump* calls and so
  wobbles with arrival interleaving.  A field regresses when it is
  worse than ``base * (1 + tol)`` (or ``base / (1 + tol)`` for
  higher-is-better fields).  Defaults are sized to sit well above
  run-to-run jitter on a loaded CI box yet catch a 2x wall regression:
  ``--wall-tol 0.5`` (also latency), ``--throughput-tol 0.35``,
  ``--nfe-tol 0.25``.  Improvements never fail the gate.
* **exact-match** for the token-parity / structural claims the paper
  rests on: ``comparison.solo_parity`` and ``comparison.fewer_nfe`` in
  serving artifacts, method coverage (a method present in BASE must be
  present in NEW), schema version and artifact kind.  These encode
  "continuous batching still reproduces the solo tokens with fewer
  calls" — any flip is a regression regardless of magnitude.

The report prints one line per comparison (``ok``/``REGRESSION``) so
the CI log shows *what* moved, not just that something did.
"""
from __future__ import annotations

import argparse
import json
import sys

BETTER_LOW = "low"          # lower is better (wall, latency, nfe)
BETTER_HIGH = "high"        # higher is better (throughput, tokens/s)


class _Gate:
    def __init__(self):
        self.failures: list[str] = []
        self.lines: list[str] = []

    def rel(self, path: str, base: float, new: float, tol: float,
            better: str) -> None:
        if base <= 0:       # degenerate baseline: nothing to gate on
            self.lines.append(f"ok         {path}: base={base:g} (skipped)")
            return
        if better == BETTER_LOW:
            worse = new > base * (1.0 + tol)
        else:
            worse = new < base / (1.0 + tol)
        delta = (new - base) / base
        tag = "REGRESSION" if worse else "ok"
        self.lines.append(f"{tag:<10} {path}: {base:g} -> {new:g} "
                          f"({delta:+.1%}, tol {tol:.0%})")
        if worse:
            self.failures.append(path)

    def exact(self, path: str, base, new, degrade_only: bool = False) -> None:
        """``degrade_only``: only a True->False flip fails (a baseline
        that never had the property cannot regress it)."""
        bad = (base != new) if not degrade_only else (bool(base)
                                                     and not bool(new))
        tag = "REGRESSION" if bad else "ok"
        self.lines.append(f"{tag:<10} {path}: {base!r} -> {new!r} (exact)")
        if bad:
            self.failures.append(path)


def _compare_bench(base: dict, new: dict, g: _Gate, tols: dict) -> None:
    for m, b in sorted(base["methods"].items()):
        n = new["methods"].get(m)
        if n is None:
            g.exact(f"methods.{m}", "present", "MISSING")
            continue
        g.rel(f"methods.{m}.wall_seconds", b["wall_seconds"],
              n["wall_seconds"], tols["wall"], BETTER_LOW)
        g.rel(f"methods.{m}.tokens_per_second", b["tokens_per_second"],
              n["tokens_per_second"], tols["throughput"], BETTER_HIGH)
        g.rel(f"methods.{m}.nfe", b["nfe"], n["nfe"], tols["nfe"],
              BETTER_LOW)


def _compare_serving(base: dict, new: dict, g: _Gate, tols: dict) -> None:
    for mode, b in sorted(base["modes"].items()):
        n = new["modes"].get(mode)
        if n is None:
            g.exact(f"modes.{mode}", "present", "MISSING")
            continue
        p = f"modes.{mode}"
        g.rel(f"{p}.wall_seconds", b["wall_seconds"], n["wall_seconds"],
              tols["wall"], BETTER_LOW)
        g.rel(f"{p}.throughput_rps", b["throughput_rps"],
              n["throughput_rps"], tols["throughput"], BETTER_HIGH)
        for q in ("latency_p50_s", "latency_p95_s", "latency_p99_s"):
            if q in b and q in n:
                g.rel(f"{p}.{q}", b[q], n[q], tols["wall"], BETTER_LOW)
        g.rel(f"{p}.aggregate_nfe", b["aggregate_nfe"],
              n["aggregate_nfe"], tols["nfe"], BETTER_LOW)
    bc, nc = base.get("comparison", {}), new.get("comparison", {})
    g.exact("comparison.solo_parity", bc.get("solo_parity"),
            nc.get("solo_parity"), degrade_only=True)
    g.exact("comparison.fewer_nfe", bc.get("fewer_nfe"),
            nc.get("fewer_nfe"), degrade_only=True)


def compare(base: dict, new: dict, wall_tol: float = 0.5,
            throughput_tol: float = 0.35,
            nfe_tol: float = 0.25) -> tuple[bool, list[str]]:
    """Returns (ok, report_lines).  ``ok`` is False on any regression."""
    g = _Gate()
    tols = {"wall": wall_tol, "throughput": throughput_tol,
            "nfe": nfe_tol}
    g.exact("schema", base.get("schema"), new.get("schema"))
    g.exact("kind", base.get("kind"), new.get("kind"))
    if g.failures:
        return False, g.lines
    if base.get("kind") == "serving":
        _compare_serving(base, new, g, tols)
    else:
        _compare_bench(base, new, g, tols)
    return not g.failures, g.lines


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.regress",
        description="Gate a new benchmark artifact against a baseline.")
    ap.add_argument("base", help="baseline artifact (committed)")
    ap.add_argument("new", help="freshly produced artifact")
    ap.add_argument("--wall-tol", type=float, default=0.5,
                    help="relative tolerance for wall/latency (default "
                         "0.5 = +50%% passes, 2x fails)")
    ap.add_argument("--throughput-tol", type=float, default=0.35)
    ap.add_argument("--nfe-tol", type=float, default=0.25)
    args = ap.parse_args(argv)
    try:
        with open(args.base) as f:
            base = json.load(f)
        with open(args.new) as f:
            new = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"regress: cannot read artifact: {e}", file=sys.stderr)
        return 2
    ok, lines = compare(base, new, wall_tol=args.wall_tol,
                        throughput_tol=args.throughput_tol,
                        nfe_tol=args.nfe_tol)
    for line in lines:
        print(line)
    n_bad = sum(line.startswith("REGRESSION") for line in lines)
    if ok:
        print(f"regress: OK ({len(lines)} comparisons, 0 regressions)")
        return 0
    print(f"regress: FAILED ({n_bad} regression"
          f"{'s' if n_bad != 1 else ''})", file=sys.stderr)
    return 1


if __name__ == "__main__":
    raise SystemExit(main())
