"""Nestable trace spans with a JSON-lines exporter.

A span is a timed region (``with obs.span("engine.generate", method=m)``)
that records name, wall duration, attributes, and its parent span — the
nesting is tracked per-thread, so a scheduler batch span contains the
engine span which contains the per-step sampler events.  An *event* is a
point-in-time record attached to the current span.

When disabled (the default), :func:`span` returns a shared no-op
singleton and :func:`event` returns after one guard check — nothing is
allocated or recorded.  When enabled, records accumulate in a bounded
in-memory buffer (``records()``/:func:`summary`) and, if a sink is set
(``REPRO_TRACE=path.jsonl`` or :func:`set_sink`), each record is also
appended to the file as one JSON line.  The export schema is documented
and validated in :mod:`repro.obs.schema`.

``maybe_jax_profile()`` is the optional device-level hook: when
``REPRO_JAX_PROFILE=dir`` is set it wraps the region in
``jax.profiler.trace(dir)`` (TPU/TensorBoard traces); otherwise it is
the same no-op singleton.
"""
from __future__ import annotations

import atexit
import itertools
import json
import os
import threading
import time

from repro.obs import metrics as _metrics

# In-memory record bound: _emit keeps the first _MAX_RECORDS records and
# counts (never silently swallows) everything after — the drop total is
# the obs.trace.dropped_records counter, shows up in summary() and in
# the metrics footer record close_sink(final_metrics=True) appends.  A
# sink keeps receiving every record regardless: only the in-memory
# buffer is bounded.
_MAX_RECORDS = 200_000

# sink buffering: one write+flush per record made tracing the hot path's
# dominant syscall cost; records now accumulate and hit the file every
# _SINK_FLUSH_RECORDS records or _SINK_FLUSH_SECONDS since the last
# flush, plus always on flush_sink()/close_sink()/set_sink()
_SINK_FLUSH_RECORDS = 256
_SINK_FLUSH_SECONDS = 1.0

_tls = threading.local()
_next_id = itertools.count(1).__next__
_records: list[dict] = []
_dropped = 0
_sink = None
_sink_path: str | None = None
_sink_buf: list[str] = []
_sink_last_flush = 0.0
_sink_lock = threading.Lock()


def _stack() -> list:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


def _coerce(v):
    """Attribute values must be JSON scalars; numpy/jax scalars unwrap."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if hasattr(v, "item"):
        try:
            return v.item()
        except Exception:           # noqa: BLE001 — fall through to str
            pass
    return str(v)


def _emit(rec: dict) -> None:
    global _dropped
    if len(_records) < _MAX_RECORDS:
        _records.append(rec)
    else:
        _dropped += 1
        _metrics.counter(
            "obs.trace.dropped_records",
            "trace records past the in-memory bound (_MAX_RECORDS); "
            "the file sink still received them").inc()
    if _sink is not None:
        with _sink_lock:
            _sink_buf.append(json.dumps(rec) + "\n")
            if (len(_sink_buf) >= _SINK_FLUSH_RECORDS
                    or time.time() - _sink_last_flush
                    >= _SINK_FLUSH_SECONDS):
                _flush_locked()


def _flush_locked() -> None:
    global _sink_last_flush
    if _sink is not None and _sink_buf:
        _sink.write("".join(_sink_buf))
        _sink.flush()
    _sink_buf.clear()
    _sink_last_flush = time.time()


def flush_sink() -> None:
    """Force buffered records to the sink file (tests, live tailing)."""
    with _sink_lock:
        _flush_locked()


def dropped_records() -> int:
    """Records discarded from the in-memory buffer (sink unaffected)."""
    return _dropped


class _NullSpan:
    """Shared do-nothing span: the disabled path allocates nothing."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


NULL_SPAN = _NullSpan()


class Span:
    __slots__ = ("name", "attrs", "span_id", "parent_id", "ts", "_t0")

    def __init__(self, name: str, attrs: dict):
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        st = _stack()
        self.parent_id = st[-1].span_id if st else None
        self.span_id = _next_id()
        self.ts = time.time()
        self._t0 = time.perf_counter()
        st.append(self)
        return self

    def set(self, **attrs):
        self.attrs.update(attrs)
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter() - self._t0
        st = _stack()
        if st and st[-1] is self:
            st.pop()
        _emit({"kind": "span", "name": self.name, "ts": self.ts,
               "span_id": self.span_id, "parent_id": self.parent_id,
               "dur_s": dur,
               "attrs": {k: _coerce(v) for k, v in self.attrs.items()}})
        return False


def span(name: str, **attrs):
    """Timed region; no-op singleton when telemetry is disabled."""
    if not _metrics.enabled():
        return NULL_SPAN
    return Span(name, attrs)


def event(name: str, **attrs) -> None:
    """Point-in-time record under the current span."""
    if not _metrics.enabled():
        return
    st = _stack()
    _emit({"kind": "event", "name": name, "ts": time.time(),
           "span_id": _next_id(),
           "parent_id": st[-1].span_id if st else None,
           "attrs": {k: _coerce(v) for k, v in attrs.items()}})


def write_metrics_record() -> None:
    """Append the current metrics snapshot as one trace record.

    The footer record a trace file ends with (``close_sink(
    final_metrics=True)``): alongside every live metric it carries
    ``obs.trace.dropped_records`` whenever the in-memory buffer
    overflowed, so a truncated ``records()`` view is always detectable
    from the file alone.
    """
    if not _metrics.enabled():
        return
    if _dropped:        # counter may predate enable(); pin the total
        _metrics.gauge("obs.trace.dropped_records_total",
                       "final in-memory drop total").set(_dropped)
    _emit({"kind": "metrics", "ts": time.time(), "span_id": _next_id(),
           "parent_id": None, "attrs": {},
           "metrics": _metrics.snapshot()})


def set_sink(path: str) -> None:
    """Open (append) a JSON-lines sink; closes any previous sink."""
    global _sink, _sink_path, _sink_last_flush
    close_sink()
    with _sink_lock:
        _sink = open(path, "a")
        _sink_path = path
        _sink_last_flush = time.time()


def close_sink(final_metrics: bool = False) -> None:
    global _sink, _sink_path
    if _sink is None:
        return
    if final_metrics:
        write_metrics_record()
    with _sink_lock:
        _flush_locked()
        _sink.close()
        _sink = None
        _sink_path = None


def sink_path() -> str | None:
    return _sink_path


# The sink is write-buffered (_SINK_FLUSH_RECORDS); a process that sets
# REPRO_TRACE and exits without close_sink() must not lose the tail.
atexit.register(close_sink)


def records() -> list[dict]:
    return list(_records)


def clear() -> None:
    global _dropped
    _records.clear()
    _dropped = 0
    _tls.stack = []


def summary() -> str:
    """Human-readable roll-up: spans aggregated by name, then metrics."""
    agg: dict[str, list[float]] = {}
    for r in _records:
        if r["kind"] == "span":
            agg.setdefault(r["name"], []).append(r["dur_s"])
    lines = ["== spans ==",
             f"{'name':<28} {'count':>6} {'total_s':>9} {'mean_s':>9} "
             f"{'max_s':>9}"]
    for name in sorted(agg):
        d = agg[name]
        lines.append(f"{name:<28} {len(d):>6} {sum(d):>9.4f} "
                     f"{sum(d) / len(d):>9.4f} {max(d):>9.4f}")
    if _dropped:
        lines.append(f"!! {_dropped} trace records dropped from the "
                     f"in-memory buffer (bound {_MAX_RECORDS}); the span "
                     "table above is a truncated view (file sink, if "
                     "set, is complete)")
    lines.append("== metrics ==")
    for name, inst in sorted(_metrics.snapshot().items()):
        for s in inst["series"]:
            labels = ",".join(f"{k}={v}" for k, v in s["labels"].items())
            v = s["value"]
            if isinstance(v, dict):                     # histogram stats
                v = (f"count={v['count']} mean={v['mean']:.4g} "
                     f"min={v['min']:.4g} max={v['max']:.4g} "
                     f"p50={v['p50']:.4g} p95={v['p95']:.4g} "
                     f"p99={v['p99']:.4g}")
            lines.append(f"{name}{{{labels}}} {v}")
    return "\n".join(lines)


# ------------------------------------------------------------------
# per-request timelines
# ------------------------------------------------------------------

def _matches(rec: dict, request_id: str) -> bool:
    a = rec.get("attrs", {})
    if a.get("request_id") == request_id:
        return True
    ids = a.get("request_ids")
    return bool(ids) and request_id in str(ids).split(",")


def timeline(request_id: str, path: str | None = None) -> list[dict]:
    """One request's full lifecycle, reconstructed from the trace.

    Returns every record that names ``request_id`` — directly via an
    ``attrs.request_id`` / ``attrs.request_ids`` entry (submit /
    admission / completion events, the batched ``engine.stepwise`` and
    ``scheduler.batch`` spans the request rode) — plus every record
    nested (transitively) under one of those spans, e.g. the
    ``engine.generate`` span and its ``sampler.step`` events inside a
    drain batch.  Sorted by timestamp: submit → admission → each
    batched network call → completion.

    Reads the in-memory buffer by default; pass ``path`` to reconstruct
    from a trace *file* instead (works in a fresh process, which is the
    point of the JSONL export).  Note spans are emitted at exit, so a
    span's file position is later than its children's — ``ts`` (span
    start time) is the sort key that restores causal order.
    """
    if path is not None:
        with open(path) as f:
            recs = [json.loads(line) for line in f if line.strip()]
    else:
        flush_sink()
        recs = list(_records)
    direct = [r for r in recs if _matches(r, request_id)]
    want = {r["span_id"] for r in direct}
    parents = {r["span_id"]: r.get("parent_id") for r in recs}
    out = list(direct)
    for r in recs:
        if r["span_id"] in want:
            continue
        pid = r.get("parent_id")
        seen = set()
        while pid is not None and pid not in seen:
            if pid in want:
                out.append(r)
                want.add(r["span_id"])
                break
            seen.add(pid)
            pid = parents.get(pid)
    return sorted(out, key=lambda r: (r["ts"], r["span_id"]))


class _Profile:
    """jax.profiler.trace wrapper that never breaks the serving path."""

    __slots__ = ("dir", "_cm")

    def __init__(self, dir: str):
        self.dir = dir
        self._cm = None

    def __enter__(self):
        try:
            import jax
            self._cm = jax.profiler.trace(self.dir)
            self._cm.__enter__()
        except Exception:           # noqa: BLE001 — profiling is best-effort
            self._cm = None
        return self

    def __exit__(self, *exc):
        if self._cm is not None:
            try:
                self._cm.__exit__(*exc)
            except Exception:       # noqa: BLE001
                pass
        return False


def maybe_jax_profile():
    """``jax.profiler.trace`` context if ``REPRO_JAX_PROFILE=dir`` is set."""
    d = os.environ.get("REPRO_JAX_PROFILE", "").strip()
    if not d:
        return NULL_SPAN
    return _Profile(d)
