"""Nestable trace spans with a JSON-lines exporter.

A span is a timed region (``with obs.span("engine.generate", method=m)``)
that records name, wall duration, attributes, and its parent span — the
nesting is tracked per-thread, so a scheduler batch span contains the
engine span which contains the per-step sampler events.  An *event* is a
point-in-time record attached to the current span.

When disabled (the default), :func:`span` returns a shared no-op
singleton and :func:`event` returns after one guard check — nothing is
allocated or recorded.  When enabled, records accumulate in a bounded
in-memory buffer (``records()``/:func:`summary`) and, if a sink is set
(``REPRO_TRACE=path.jsonl`` or :func:`set_sink`), each record is also
appended to the file as one JSON line.  The export schema is documented
and validated in :mod:`repro.obs.schema`.

``maybe_jax_profile()`` is the optional device-level hook: when
``REPRO_JAX_PROFILE=dir`` is set it wraps the region in
``jax.profiler.trace(dir)`` (TPU/TensorBoard traces); otherwise it is
the same no-op singleton.
"""
from __future__ import annotations

import itertools
import json
import os
import threading
import time

from repro.obs import metrics as _metrics

_MAX_RECORDS = 200_000

_tls = threading.local()
_next_id = itertools.count(1).__next__
_records: list[dict] = []
_sink = None
_sink_path: str | None = None


def _stack() -> list:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


def _coerce(v):
    """Attribute values must be JSON scalars; numpy/jax scalars unwrap."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if hasattr(v, "item"):
        try:
            return v.item()
        except Exception:           # noqa: BLE001 — fall through to str
            pass
    return str(v)


def _emit(rec: dict) -> None:
    if len(_records) < _MAX_RECORDS:
        _records.append(rec)
    if _sink is not None:
        _sink.write(json.dumps(rec) + "\n")
        _sink.flush()


class _NullSpan:
    """Shared do-nothing span: the disabled path allocates nothing."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


NULL_SPAN = _NullSpan()


class Span:
    __slots__ = ("name", "attrs", "span_id", "parent_id", "ts", "_t0")

    def __init__(self, name: str, attrs: dict):
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        st = _stack()
        self.parent_id = st[-1].span_id if st else None
        self.span_id = _next_id()
        self.ts = time.time()
        self._t0 = time.perf_counter()
        st.append(self)
        return self

    def set(self, **attrs):
        self.attrs.update(attrs)
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter() - self._t0
        st = _stack()
        if st and st[-1] is self:
            st.pop()
        _emit({"kind": "span", "name": self.name, "ts": self.ts,
               "span_id": self.span_id, "parent_id": self.parent_id,
               "dur_s": dur,
               "attrs": {k: _coerce(v) for k, v in self.attrs.items()}})
        return False


def span(name: str, **attrs):
    """Timed region; no-op singleton when telemetry is disabled."""
    if not _metrics.enabled():
        return NULL_SPAN
    return Span(name, attrs)


def event(name: str, **attrs) -> None:
    """Point-in-time record under the current span."""
    if not _metrics.enabled():
        return
    st = _stack()
    _emit({"kind": "event", "name": name, "ts": time.time(),
           "span_id": _next_id(),
           "parent_id": st[-1].span_id if st else None,
           "attrs": {k: _coerce(v) for k, v in attrs.items()}})


def write_metrics_record() -> None:
    """Append the current metrics snapshot as one trace record."""
    if not _metrics.enabled():
        return
    _emit({"kind": "metrics", "ts": time.time(), "span_id": _next_id(),
           "parent_id": None, "attrs": {},
           "metrics": _metrics.snapshot()})


def set_sink(path: str) -> None:
    """Open (append) a JSON-lines sink; closes any previous sink."""
    global _sink, _sink_path
    close_sink()
    _sink = open(path, "a")
    _sink_path = path


def close_sink(final_metrics: bool = False) -> None:
    global _sink, _sink_path
    if _sink is None:
        return
    if final_metrics:
        write_metrics_record()
    _sink.close()
    _sink = None
    _sink_path = None


def sink_path() -> str | None:
    return _sink_path


def records() -> list[dict]:
    return list(_records)


def clear() -> None:
    _records.clear()
    _tls.stack = []


def summary() -> str:
    """Human-readable roll-up: spans aggregated by name, then metrics."""
    agg: dict[str, list[float]] = {}
    for r in _records:
        if r["kind"] == "span":
            agg.setdefault(r["name"], []).append(r["dur_s"])
    lines = ["== spans ==",
             f"{'name':<28} {'count':>6} {'total_s':>9} {'mean_s':>9} "
             f"{'max_s':>9}"]
    for name in sorted(agg):
        d = agg[name]
        lines.append(f"{name:<28} {len(d):>6} {sum(d):>9.4f} "
                     f"{sum(d) / len(d):>9.4f} {max(d):>9.4f}")
    lines.append("== metrics ==")
    for name, inst in sorted(_metrics.snapshot().items()):
        for s in inst["series"]:
            labels = ",".join(f"{k}={v}" for k, v in s["labels"].items())
            v = s["value"]
            if isinstance(v, dict):                     # histogram stats
                v = (f"count={v['count']} mean={v['mean']:.4g} "
                     f"min={v['min']:.4g} max={v['max']:.4g}")
            lines.append(f"{name}{{{labels}}} {v}")
    return "\n".join(lines)


class _Profile:
    """jax.profiler.trace wrapper that never breaks the serving path."""

    __slots__ = ("dir", "_cm")

    def __init__(self, dir: str):
        self.dir = dir
        self._cm = None

    def __enter__(self):
        try:
            import jax
            self._cm = jax.profiler.trace(self.dir)
            self._cm.__enter__()
        except Exception:           # noqa: BLE001 — profiling is best-effort
            self._cm = None
        return self

    def __exit__(self, *exc):
        if self._cm is not None:
            try:
                self._cm.__exit__(*exc)
            except Exception:       # noqa: BLE001
                pass
        return False


def maybe_jax_profile():
    """``jax.profiler.trace`` context if ``REPRO_JAX_PROFILE=dir`` is set."""
    d = os.environ.get("REPRO_JAX_PROFILE", "").strip()
    if not d:
        return NULL_SPAN
    return _Profile(d)
