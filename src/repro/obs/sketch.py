"""Fixed-memory mergeable quantile sketch (DDSketch-style).

Latency and NFE distributions are long-tailed, so the decade-bucket
histograms that back ``obs.histogram`` can only answer "which decade" —
useless for p95/p99 SLOs.  This module adds a relative-error sketch in
the style of DDSketch (Masson et al., VLDB 2019): values are mapped to
geometric buckets ``(gamma^(i-1), gamma^i]`` with
``gamma = (1 + alpha) / (1 - alpha)``, so any quantile estimate is
within a factor ``(1 ± alpha)`` of the true value — **relative** error
``alpha`` (default 1%), independent of the value's magnitude.

Guarantees (relied on by the exporter, the serving benchmark JSON and
the regression gate):

* ``quantile(q)`` has relative error ≤ ``alpha`` for every recorded
  value above the collapse floor (see below);
* memory is fixed: at most ``max_bins`` buckets.  When a recording
  would exceed the bound, the *lowest* buckets are collapsed into one —
  upper quantiles (p50/p95/p99, the ones SLOs care about) keep their
  guarantee, only the extreme low tail degrades;
* ``merge`` is exact bucket-count addition — associative and
  commutative, so per-shard sketches combine into the same sketch as a
  single global one (property-tested in tests/test_properties.py);
* values ``<= 0`` land in a dedicated zero bucket (exact, rank 0).

``to_dict`` / ``from_dict`` round-trip the full state through JSON —
snapshots carry the serialized sketch so readers can compute *any*
quantile after the fact (``quantile_of_snapshot``), not just the
p50/p95/p99 pre-computed by ``Histogram._snapshot_value``.
"""
from __future__ import annotations

import math

DEFAULT_ALPHA = 0.01
DEFAULT_MAX_BINS = 2048


class DDSketch:
    __slots__ = ("alpha", "gamma", "_log_gamma", "max_bins", "bins",
                 "zeros", "count", "_min_key")

    def __init__(self, alpha: float = DEFAULT_ALPHA,
                 max_bins: int = DEFAULT_MAX_BINS):
        if not 0.0 < alpha < 1.0:
            raise ValueError(f"alpha must be in (0, 1), got {alpha}")
        self.alpha = alpha
        self.gamma = (1.0 + alpha) / (1.0 - alpha)
        self._log_gamma = math.log(self.gamma)
        self.max_bins = max_bins
        self.bins: dict[int, int] = {}      # bucket index -> count
        self.zeros = 0                      # values <= 0 (exact bucket)
        self.count = 0
        self._min_key: int | None = None    # collapse floor, lazily known

    def _key(self, value: float) -> int:
        # bucket i covers (gamma^(i-1), gamma^i]
        return math.ceil(math.log(value) / self._log_gamma)

    def _value(self, key: int) -> float:
        # midpoint estimator: est/true in [1-alpha, 1+alpha] over the bin
        return 2.0 * self.gamma ** key / (self.gamma + 1.0)

    def add(self, value: float, n: int = 1) -> None:
        self.count += n
        if value <= 0.0:
            self.zeros += n
            return
        k = self._key(value)
        self.bins[k] = self.bins.get(k, 0) + n
        if len(self.bins) > self.max_bins:
            self._collapse()

    def _collapse(self) -> None:
        """Fold the lowest buckets into one until within ``max_bins``.

        Collapsing low (not high) keeps the upper-quantile guarantee:
        p95/p99 sit in the highest buckets, which are never merged.
        """
        keys = sorted(self.bins)
        spill = 0
        while len(keys) > self.max_bins:
            spill += self.bins.pop(keys.pop(0))
        if spill:
            floor = keys[0]
            self.bins[floor] += spill
            self._min_key = floor

    def merge(self, other: "DDSketch") -> "DDSketch":
        """In-place exact merge (bucket-count addition); returns self."""
        if abs(other.alpha - self.alpha) > 1e-12:
            raise ValueError("cannot merge sketches with different alpha")
        for k, c in other.bins.items():
            self.bins[k] = self.bins.get(k, 0) + c
        self.zeros += other.zeros
        self.count += other.count
        if len(self.bins) > self.max_bins:
            self._collapse()
        return self

    def quantile(self, q: float) -> float:
        """Value at quantile ``q`` in [0, 1]; 0.0 on an empty sketch.

        Rank semantics: the returned estimate covers the value of the
        element at (0-based) rank ``floor(q * (count - 1))`` in the
        sorted stream — the same convention as ``numpy.percentile`` with
        nearest-rank interpolation, up to the bucket's relative error.
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if self.count == 0:
            return 0.0
        rank = int(q * (self.count - 1))
        if rank < self.zeros:
            return 0.0
        seen = self.zeros
        for k in sorted(self.bins):
            seen += self.bins[k]
            if seen > rank:
                return self._value(k)
        return self._value(max(self.bins))      # q == 1 safety

    def copy(self) -> "DDSketch":
        s = DDSketch(self.alpha, self.max_bins)
        s.bins = dict(self.bins)
        s.zeros = self.zeros
        s.count = self.count
        s._min_key = self._min_key
        return s

    def to_dict(self) -> dict:
        """JSON-able full state (bucket keys become strings)."""
        return {"alpha": self.alpha, "max_bins": self.max_bins,
                "zeros": self.zeros, "count": self.count,
                "bins": {str(k): c for k, c in self.bins.items()}}

    @classmethod
    def from_dict(cls, d: dict) -> "DDSketch":
        s = cls(d["alpha"], d.get("max_bins", DEFAULT_MAX_BINS))
        s.bins = {int(k): int(c) for k, c in d["bins"].items()}
        s.zeros = int(d["zeros"])
        s.count = int(d["count"])
        return s


def quantile_of_snapshot(hist_value: dict, q: float) -> float:
    """Quantile from a histogram *snapshot* series value (the JSON form
    carrying a serialized ``"sketch"``) — what artifact readers and the
    regression gate use to query arbitrary quantiles post hoc."""
    return DDSketch.from_dict(hist_value["sketch"]).quantile(q)
