"""Live metrics export: Prometheus text + JSON snapshot over HTTP, and a
periodic snapshot-file writer for headless runs.

Stdlib only (``http.server`` on a daemon thread).  Endpoints:

* ``GET /metrics``  — Prometheus text exposition format.  Counters and
  gauges map 1:1 (metric dots become underscores); histograms export as
  Prometheus *summaries*: one ``{quantile="0.5|0.95|0.99"}`` sample per
  series straight from the DDSketch (≤ 1% relative error, see
  :mod:`repro.obs.sketch`) plus ``_sum`` / ``_count``.
* ``GET /snapshot`` — the full ``obs.snapshot()`` JSON (including the
  serialized sketches, so any quantile is recoverable client-side).

Enable with ``REPRO_METRICS_PORT=9099`` (read by
``obs.configure_from_env``; also turns metrics on) or programmatically::

    srv = exporter.serve(port=0)        # 0 = ephemeral, srv.port tells
    ...
    srv.stop()

Reads are safe against concurrent recording: ``metrics.snapshot()``
takes the registry lock every record call holds and returns a fresh
deep copy, so the exporter thread never serves a torn series.

``start_snapshot_writer(path, interval_s)`` (env:
``REPRO_SNAPSHOT=path``, ``REPRO_SNAPSHOT_INTERVAL=5``) writes the same
JSON snapshot to a file every interval — atomic tmp+rename, so a reader
never sees a half-written file — for runs where nothing can scrape.

``parse_prometheus_text`` is the deliberately minimal parser the tests
and the CI ``obs-live`` leg round-trip the exposition through.
"""
from __future__ import annotations

import atexit
import json
import os
import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.obs import metrics as _metrics

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    return _NAME_RE.sub("_", name)


def _prom_labels(labels: dict) -> str:
    if not labels:
        return ""
    body = ",".join(f'{_prom_name(str(k))}="{v}"'
                    for k, v in sorted(labels.items()))
    return "{" + body + "}"


def _sample(name: str, labels: dict, value, extra: dict | None = None):
    merged = dict(labels, **(extra or {}))
    return f"{name}{_prom_labels(merged)} {float(value):g}"


def prometheus_text(snap: dict | None = None) -> str:
    """Render a metrics snapshot (default: the live registry) as
    Prometheus text exposition format."""
    if snap is None:
        snap = _metrics.snapshot()
    lines: list[str] = []
    for name, inst in sorted(snap.items()):
        pname = _prom_name(name)
        kind = inst["type"]
        if inst.get("help"):
            lines.append(f"# HELP {pname} {inst['help']}")
        lines.append(f"# TYPE {pname} "
                     f"{'summary' if kind == 'histogram' else kind}")
        for s in inst["series"]:
            labels, v = s["labels"], s["value"]
            if kind == "histogram":
                for q in _metrics.QUANTILES:
                    lines.append(_sample(pname, labels,
                                         v[f"p{int(q * 100)}"],
                                         {"quantile": f"{q:g}"}))
                lines.append(_sample(pname + "_sum", labels, v["sum"]))
                lines.append(_sample(pname + "_count", labels, v["count"]))
            else:
                lines.append(_sample(pname, labels, v))
    return "\n".join(lines) + "\n"


def parse_prometheus_text(text: str) -> dict:
    """Minimal exposition parser: {(name, ((label, value), ...)): float}.

    Understands exactly what :func:`prometheus_text` emits (comments,
    ``name{l="v",...} value`` samples) — enough to round-trip our own
    output and to let the CI leg assert on scraped quantiles.
    """
    out: dict = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = re.fullmatch(
            r"([a-zA-Z_:][a-zA-Z0-9_:]*)(?:\{(.*)\})?\s+(\S+)", line)
        if not m:
            raise ValueError(f"unparseable sample line: {line!r}")
        name, labelstr, value = m.groups()
        labels = []
        if labelstr:
            for part in re.findall(r'([a-zA-Z0-9_:]+)="([^"]*)"',
                                   labelstr):
                labels.append(part)
        out[(name, tuple(sorted(labels)))] = float(value)
    return out


class _Handler(BaseHTTPRequestHandler):
    def _send(self, code: int, body: bytes, ctype: str) -> None:
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self):  # noqa: N802 — http.server API
        if self.path.split("?")[0] == "/metrics":
            self._send(200, prometheus_text().encode(),
                       "text/plain; version=0.0.4; charset=utf-8")
        elif self.path.split("?")[0] == "/snapshot":
            body = json.dumps(_metrics.snapshot(), sort_keys=True).encode()
            self._send(200, body, "application/json")
        else:
            self._send(404, b"try /metrics or /snapshot\n", "text/plain")

    def log_message(self, *args):        # scrapes must not spam stderr
        pass


class MetricsServer:
    """Background HTTP exporter; ``port=0`` binds an ephemeral port."""

    def __init__(self, port: int, host: str = "127.0.0.1"):
        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._httpd.daemon_threads = True
        self.host = host
        self.port = self._httpd.server_address[1]
        self.url = f"http://{host}:{self.port}"
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="repro-obs-exporter",
            daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)


class SnapshotWriter:
    """Periodic snapshot-file writer for headless runs."""

    def __init__(self, path: str, interval_s: float = 5.0):
        self.path = path
        self.interval_s = interval_s
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._loop, name="repro-obs-snapshot", daemon=True)
        self._thread.start()

    def _write(self) -> None:
        tmp = f"{self.path}.tmp"
        with open(tmp, "w") as f:
            json.dump(_metrics.snapshot(), f, sort_keys=True)
            f.write("\n")
        os.replace(tmp, self.path)      # atomic: readers never see half

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            self._write()

    def stop(self, final: bool = True) -> None:
        self._stop.set()
        self._thread.join(timeout=5)
        if final:
            self._write()


_server: MetricsServer | None = None
_writer: SnapshotWriter | None = None


def serve(port: int = 9099, host: str = "127.0.0.1") -> MetricsServer:
    """Start (or return the already-running) exporter."""
    global _server
    if _server is None:
        _server = MetricsServer(port, host)
    return _server


def start_snapshot_writer(path: str,
                          interval_s: float = 5.0) -> SnapshotWriter:
    global _writer
    if _writer is None:
        _writer = SnapshotWriter(path, interval_s)
    return _writer


def stop() -> None:
    """Tear down the exporter and the snapshot writer (tests)."""
    global _server, _writer
    if _server is not None:
        _server.stop()
        _server = None
    if _writer is not None:
        _writer.stop()
        _writer = None


def _final_snapshot() -> None:
    # a REPRO_SNAPSHOT run shorter than the interval must still leave a
    # snapshot file behind (the writer thread may never have fired)
    if _writer is not None:
        _writer.stop(final=True)


atexit.register(_final_snapshot)
