"""Sampler API shared by every reverse-process algorithm.

A *denoiser* is any callable ``denoise_fn(x_t, t_norm, cond) -> logits``:
  x_t    : (B, N) int32 current tokens
  t_norm : (B,) float32 time in [0, 1] (t/T for discrete samplers)
  cond   : optional dict of conditioning tensors (e.g. encoder output)
  logits : (B, N, K)

Samplers are model-agnostic: the model zoo, the oracle test denoisers and
the tiny trained checkpoints all expose this signature.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.noise import NoiseDist

Array = jnp.ndarray
DenoiseFn = Callable[[Array, Array, Any], Array]


class SamplerOutput(NamedTuple):
    tokens: Array          # (B, N) final x_0
    nfe: int               # network calls actually made for this batch
    aux: dict              # trace / diagnostics


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    """Common knobs (paper §3.2, App. E/F)."""

    x0_mode: str = "sample"        # "sample" | "argmax"
    temperature: float = 1.0
    trace: bool = False            # record intermediate states


def select_x0(key: jax.Array, logits: Array, noise: NoiseDist,
              cfg: SamplerConfig) -> tuple[Array, Array]:
    """Pick x0_hat from logits; returns (tokens (B,N), scores (B,N)).

    Thin shim over :func:`repro.core.decode.decode_tokens`, kept for API
    stability — the decode layer owns the backend selection (streaming
    pallas/interpret kernel vs pure-jnp reference) and the Gumbel-max
    sample mode.
    """
    from repro.core import decode
    return decode.decode_tokens(key, logits, noise, cfg)


def init_noise_tokens(key: jax.Array, noise: NoiseDist, batch: int,
                      N: int) -> Array:
    """x_T ~ q_noise for every token."""
    return noise.sample(key, (batch, N)).astype(jnp.int32)
