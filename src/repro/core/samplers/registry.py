"""Sampler registry — method dispatch as data.

One :class:`SamplerSpec` per method describes how to run it (host-driven
loop with data-dependent NFE vs. one compiled scan), its static-NFE rule,
which engine knobs it honors and which noise kinds it supports.  The
serving engine, the request scheduler, the launcher CLI, the benchmark
grids and the examples all enumerate methods from here, so adding a
sampler needs zero engine edits:

    1. write the sampler module (use ``samplers/loop.py`` for the
       skeleton and ``core/decode.py`` for the decode path);
    2. ``register(SamplerSpec(...))`` — below for built-ins, or from any
       importing module for extensions;
    3. done — the engine, CLIs and the registry smoke test pick it up.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.core.samplers import (d3pm, ddim, dndm, dndm_continuous,
                                 dndm_topk, mask_predict, rdm, stepwise)
from repro.core.samplers.base import SamplerConfig, SamplerOutput

BOTH = frozenset({"absorbing", "multinomial"})


@dataclasses.dataclass(frozen=True)
class SamplerRuntime:
    """Everything a sampler needs at call time, resolved by the engine."""

    denoise_fn: Any            # (x_t, t_norm, cond) -> logits
    noise: Any                 # NoiseDist
    schedule: Any              # discrete alpha schedule
    dist: Any                  # discrete transition-time law D_tau
    cdist: Any                 # continuous D_tau (DNDM-C)
    cfg: SamplerConfig
    steps: int
    nfe_budget: int            # 0 => default budget max(N // 2, 1)
    order: str = "iid"
    shared_tau: bool = True
    ddim_stride: int = 1


def resolved_budget(rt: SamplerRuntime, N: int) -> int:
    return rt.nfe_budget or max(N // 2, 1)


@dataclasses.dataclass(frozen=True)
class SamplerSpec:
    """How one method runs.

    ``kind="host"`` — python loop over the predetermined transition set;
    NFE is data-dependent and the engine calls ``run`` directly.
    ``kind="scan"`` — a single compiled sampler with statically known NFE
    (``static_nfe``); the engine jits ``run`` once per shape/knob key.

    ``schedule_fn(key, rt, N) -> CallSchedule`` exposes the method's
    predetermined call schedule as data — the times it will call the
    network, known at admission (every built-in provides one).
    ``stepwise_step(state, tau, t_row, keys, cond, rt) -> state`` is the
    opt-in for continuous batching: a jitted batched step advancing each
    row by one entry of its own schedule (see ``samplers/stepwise.py``);
    every built-in provides one, so the whole registry serves through
    ``ContinuousScheduler`` — methods registered without one fall back
    to drain-mode only.  ``continuous_time`` marks methods whose call
    times are real timestamps in (0, 1] (the DNDM-C family): the
    stepwise runner then keeps f32 time/tau buffers and parks free rows
    at 2.0 instead of T + 1.
    """

    name: str
    kind: str                                     # "host" | "scan"
    run: Callable[..., SamplerOutput]             # (key, rt, batch, N, cond)
    static_nfe: Callable[[SamplerRuntime, int], int] | None = None
    knobs: frozenset = frozenset()                # method-specific knobs
    noise_kinds: frozenset = BOTH
    description: str = ""
    schedule_fn: Callable[..., Any] | None = None  # (key, rt, N) -> plan
    stepwise_step: Callable[..., Any] | None = None
    continuous_time: bool = False


_REGISTRY: dict[str, SamplerSpec] = {}


def register(spec: SamplerSpec) -> SamplerSpec:
    if spec.kind not in ("host", "scan"):
        raise ValueError(f"{spec.name}: kind must be host|scan")
    if spec.kind == "scan" and spec.static_nfe is None:
        raise ValueError(f"{spec.name}: scan samplers need a static_nfe rule")
    if spec.name in _REGISTRY:
        raise ValueError(f"sampler {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> SamplerSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown sampler {name!r}; available: "
                       f"{', '.join(names())}") from None


def names(noise_kind: str | None = None) -> tuple[str, ...]:
    """Registered method names, optionally only those supporting a noise
    kind — the one enumeration every CLI/benchmark/example goes through."""
    ns = tuple(sorted(_REGISTRY))
    if noise_kind is None:
        return ns
    return tuple(n for n in ns if noise_kind in _REGISTRY[n].noise_kinds)


def specs() -> tuple[SamplerSpec, ...]:
    return tuple(_REGISTRY[n] for n in names())


def run(name: str, key, rt: SamplerRuntime, batch: int, N: int,
        cond=None) -> SamplerOutput:
    return get(name).run(key, rt, batch, N, cond)


def describe(name: str | None = None) -> str:
    """Human-readable method sheet (one line per spec) for CLIs and docs:
    kind, supported noise, honored knobs, description."""
    lines = []
    for spec in ([get(name)] if name else specs()):
        noise = "/".join(sorted(spec.noise_kinds))
        knobs = ",".join(sorted(spec.knobs)) or "-"
        lines.append(f"{spec.name:<18} {spec.kind:<4} noise={noise:<23} "
                     f"knobs={knobs:<32} {spec.description}")
    return "\n".join(lines)


# ------------------------------------------------------------------
# Built-in methods
# ------------------------------------------------------------------

def _dndm(version: int):
    def run(key, rt, batch, N, cond):
        return dndm.sample(key, rt.denoise_fn, rt.noise, rt.dist, batch, N,
                           cond=cond, cfg=rt.cfg, version=version,
                           order=rt.order, shared_tau=rt.shared_tau)
    return run


def _dndm_static(key, rt, batch, N, cond):
    return dndm.sample_static(key, rt.denoise_fn, rt.noise, rt.dist, batch,
                              N, resolved_budget(rt, N), cond=cond,
                              cfg=rt.cfg, order=rt.order,
                              shared_tau=rt.shared_tau)


def _dndm_topk(key, rt, batch, N, cond):
    return dndm_topk.sample(key, rt.denoise_fn, rt.noise, rt.dist, batch, N,
                            cond=cond, cfg=rt.cfg, order=rt.order,
                            shared_tau=rt.shared_tau)


def _dndm_topk_static(key, rt, batch, N, cond):
    return dndm_topk.sample_static(key, rt.denoise_fn, rt.noise, rt.dist,
                                   batch, N, resolved_budget(rt, N),
                                   cond=cond, cfg=rt.cfg, order=rt.order,
                                   shared_tau=rt.shared_tau)


def _dndm_c(topk: bool):
    def run(key, rt, batch, N, cond):
        return dndm_continuous.sample(key, rt.denoise_fn, rt.noise,
                                      rt.cdist, batch, N, cond=cond,
                                      cfg=rt.cfg, topk=topk, order=rt.order,
                                      shared_tau=rt.shared_tau)
    return run


def _d3pm(key, rt, batch, N, cond):
    return d3pm.sample(key, rt.denoise_fn, rt.noise, rt.schedule, batch, N,
                       cond=cond, cfg=rt.cfg)


def _rdm(topk: bool):
    def run(key, rt, batch, N, cond):
        return rdm.sample(key, rt.denoise_fn, rt.noise, rt.schedule, batch,
                          N, cond=cond, cfg=rt.cfg, topk=topk)
    return run


def _mask_predict(key, rt, batch, N, cond):
    return mask_predict.sample(key, rt.denoise_fn, rt.noise, rt.steps,
                               batch, N, cond=cond, cfg=rt.cfg)


def _ddim(key, rt, batch, N, cond):
    return ddim.sample(key, rt.denoise_fn, rt.noise, rt.schedule, batch, N,
                       stride=rt.ddim_stride, cond=cond, cfg=rt.cfg)


def _static_grid_nfe(rt: SamplerRuntime, N: int) -> int:
    """Actual NFE of the static-quantile variants: the deduped grid can
    be shorter than the requested budget (small T / concentrated D_tau)."""
    return len(dndm.quantile_grid(rt.dist, resolved_budget(rt, N)))


_TAU = frozenset({"order", "shared_tau", "beta"})

register(SamplerSpec(
    "dndm", "host", _dndm(1), knobs=_TAU,
    schedule_fn=stepwise.dndm_plan,
    stepwise_step=stepwise.dndm_stepwise(1),
    description="Algorithm 1: faithful host loop, NFE = |unique tau|"))
register(SamplerSpec(
    "dndm2", "host", _dndm(2), knobs=_TAU,
    schedule_fn=stepwise.dndm_plan,
    stepwise_step=stepwise.dndm_stepwise(2),
    description="Algorithm 3: keep refreshing revealed tokens (tau >= t)"))
register(SamplerSpec(
    "dndm_topk", "host", _dndm_topk, knobs=_TAU,
    schedule_fn=stepwise.dndm_plan,
    stepwise_step=stepwise.dndm_topk_stepwise,
    description="Algorithm 4: confidence-ranked reveal, same NFE as Alg 1"))
register(SamplerSpec(
    "dndm_static", "scan", _dndm_static, static_nfe=_static_grid_nfe,
    knobs=_TAU | {"nfe_budget"},
    schedule_fn=stepwise.static_grid_plan,
    stepwise_step=stepwise.dndm_stepwise(1),
    description="quantile-bucketized Alg 1: one compiled scan, fixed NFE"))
register(SamplerSpec(
    "dndm_topk_static", "scan", _dndm_topk_static,
    static_nfe=_static_grid_nfe, knobs=_TAU | {"nfe_budget"},
    schedule_fn=stepwise.static_grid_plan,
    stepwise_step=stepwise.dndm_topk_stepwise,
    description="quantile-bucketized Alg 4: one compiled scan, fixed NFE"))
register(SamplerSpec(
    "dndm_c", "scan", _dndm_c(False), static_nfe=lambda rt, N: N,
    knobs=_TAU, schedule_fn=stepwise.continuous_plan,
    stepwise_step=stepwise.dndm_c_stepwise(False), continuous_time=True,
    description="Algorithm 2: continuous time, NFE = N"))
register(SamplerSpec(
    "dndm_c_topk", "scan", _dndm_c(True), static_nfe=lambda rt, N: N,
    knobs=_TAU, schedule_fn=stepwise.continuous_plan,
    stepwise_step=stepwise.dndm_c_stepwise(True), continuous_time=True,
    description="Algorithm 2 + confidence-ranked reveal, NFE = N"))
register(SamplerSpec(
    "d3pm", "scan", _d3pm, static_nfe=lambda rt, N: rt.steps,
    knobs=frozenset({"steps"}), schedule_fn=stepwise.full_grid_plan,
    stepwise_step=stepwise.d3pm_stepwise,
    description="D3PM ancestral baseline, NFE = T"))
register(SamplerSpec(
    "rdm", "scan", _rdm(False), static_nfe=lambda rt, N: rt.steps,
    knobs=frozenset({"steps"}), schedule_fn=stepwise.full_grid_plan,
    stepwise_step=stepwise.rdm_stepwise(False),
    description="RDM baseline (uniform routing), NFE = T"))
register(SamplerSpec(
    "rdm_k", "scan", _rdm(True), static_nfe=lambda rt, N: rt.steps,
    knobs=frozenset({"steps"}), schedule_fn=stepwise.full_grid_plan,
    stepwise_step=stepwise.rdm_stepwise(True),
    description="RDM-k baseline (top-k routing), NFE = T"))
register(SamplerSpec(
    "mask_predict", "scan", _mask_predict,
    static_nfe=lambda rt, N: rt.steps, knobs=frozenset({"steps"}),
    noise_kinds=frozenset({"absorbing"}),
    schedule_fn=stepwise.full_grid_plan,
    stepwise_step=stepwise.mask_predict_stepwise,
    description="Mask-Predict iterative refinement, NFE = M"))
register(SamplerSpec(
    "ddim", "scan", _ddim,
    static_nfe=lambda rt, N: -(-rt.steps // rt.ddim_stride),
    knobs=frozenset({"steps", "ddim_stride"}),
    noise_kinds=frozenset({"multinomial"}),
    schedule_fn=stepwise.ddim_grid_plan,
    stepwise_step=stepwise.ddim_stepwise,
    description="discrete DDIM baseline, NFE = ceil(T / stride)"))
