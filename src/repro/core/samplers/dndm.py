"""DNDM samplers (the paper's contribution).

Three implementations of the same algorithm, trading faithfulness for
TPU-friendliness:

  * ``sample``        — Algorithm 1 (and Algorithm 3 via ``version=2``):
    the faithful host-driven loop.  Transition times are *predetermined*,
    so the host knows the unique-time set before any network call and the
    jitted step runs exactly ``|T|`` times.  NFE is data-dependent,
    exactly as in the paper.
  * ``sample_static`` — beyond-paper TPU variant: transition times are
    bucketized onto ``nfe_budget`` quantiles of D_tau at trace time, so the
    whole sampler is one ``lax.scan`` with a *fixed* NFE and compiles once.
    As nfe_budget -> |T| this converges to Algorithm 1.
  * ``sample_scan``   — fully-jitted faithful variant: scans over all T
    steps but gates the network call per step with ``lax.cond`` on
    "step hosts a transition".  Matches Algorithm 1 under the same keys;
    on TPU cond does not save FLOPs, so this exists for equivalence tests
    and as the shard_map-able inner loop.

All three decode through :func:`repro.core.decode.fused_update` — the
select-x0 + eq. (9) update is a single fused pass (streaming Pallas
kernel on TPU, pure-JAX reference elsewhere).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import decode
from repro.core.noise import NoiseDist
from repro.core.samplers import loop
from repro.core.samplers.base import DenoiseFn, SamplerConfig, SamplerOutput
from repro.core.transition import TransitionDist

Array = jnp.ndarray


@partial(jax.jit, static_argnames=("denoise_fn", "noise", "cfg", "version",
                                   "T"))
def _step(x, t, tau, k, cond, *, denoise_fn, noise, cfg, version, T):
    """One DNDM network call + fused eq. (9) decode-update.  Module-level
    so that repeated host-loop calls with the same denoiser hit the jit
    cache."""
    t_norm = jnp.full((x.shape[0],), t / T, jnp.float32)
    logits = denoise_fn(x, t_norm, cond)
    return decode.fused_update(k, logits, x, tau, t, noise, cfg,
                               version=version)


def sample(key: jax.Array, denoise_fn: DenoiseFn, noise: NoiseDist,
           dist: TransitionDist, batch: int, N: int,
           cond=None, cfg: SamplerConfig = SamplerConfig(),
           version: int = 1, order: str = "iid",
           shared_tau: bool = True) -> SamplerOutput:
    """Algorithm 1 (version=1) / Algorithm 3 (version=2) — faithful.

    The host loop below is the honest realization of "function evaluation
    only for t in T": times not in the transition set never touch the
    network, so wall-clock scales with |T|, not T.
    """
    T = dist.T
    tau, x, k_loop = loop.setup(key, noise, batch, N, dist=dist,
                                order=order, shared=shared_tau)

    # Predetermined: the whole schedule of network calls is known *now*.
    tau_np = np.asarray(jax.device_get(tau))
    times = loop.unique_times(tau_np)                          # descending

    trace = []
    aux = {"tau": tau, "trace": trace, "times": times}
    step_attrs = None
    if obs.enabled():
        # |R_t| per step — predetermined, so computed host-side from the
        # tau set already fetched above (no extra device sync).
        reveals = loop.reveal_series(tau_np, times, version=version)
        aux["reveal_counts"] = reveals
        hist = obs.histogram("sampler.reveal_count",
                             "tokens revealed per network call (|R_t|)")
        for r in reveals:
            hist.observe(float(r), sampler="dndm", version=version)
        step_attrs = lambda i, t: {"reveal": float(reveals[i])}  # noqa: E731

    def step(x, t, k):
        return _step(x, jnp.asarray(t, jnp.float32), tau, k, cond,
                     denoise_fn=denoise_fn, noise=noise, cfg=cfg,
                     version=version, T=T)

    on_step = ((lambda x: trace.append(np.asarray(jax.device_get(x))))
               if cfg.trace else None)
    x = loop.host_loop(k_loop, times, x, step, on_step=on_step,
                       step_attrs=step_attrs)
    return SamplerOutput(tokens=x, nfe=len(times), aux=aux)


def quantile_grid(dist: TransitionDist, nfe_budget: int) -> np.ndarray:
    """Grid times = D_tau quantiles (equal transition mass per call).

    Strictly increasing: when the budget exceeds the number of distinct
    quantile times (small T or concentrated D_tau) the repeats are
    dropped — a duplicated grid time would make the static scan visit t
    twice and re-sample every token with ``tau_b == t``, breaking the
    "revealed exactly once" invariant.  ``len(grid) <= nfe_budget`` is
    therefore the actual NFE of the static samplers.
    """
    probs = dist.probs
    if probs is None:
        raise ValueError("need a discretized D_tau")
    cdf = np.concatenate([[0.0], np.cumsum(probs)])
    qs = (np.arange(nfe_budget) + 1) / nfe_budget
    # smallest t with P(tau <= t) >= q  (cdf[t] indexes times directly)
    grid = np.searchsorted(cdf, qs - 1e-12)
    grid = np.clip(grid, 1, dist.T).astype(np.int32)     # times 1..T
    return np.unique(np.maximum.accumulate(grid))


def sample_static(key: jax.Array, denoise_fn: DenoiseFn, noise: NoiseDist,
                  dist: TransitionDist, batch: int, N: int,
                  nfe_budget: int, cond=None,
                  cfg: SamplerConfig = SamplerConfig(),
                  version: int = 1, order: str = "iid",
                  shared_tau: bool = True) -> SamplerOutput:
    """Beyond-paper: static-quantile DNDM — one compiled scan, NFE fixed.

    Each token's tau is rounded *up* to the nearest grid time, preserving
    "every token revealed exactly once" and the reveal order; as
    nfe_budget -> T this recovers Algorithm 1 exactly.
    """
    T = dist.T
    grid = quantile_grid(dist, nfe_budget)
    grid_j = jnp.asarray(grid)

    tau, x, k_loop = loop.setup(key, noise, batch, N, dist=dist,
                                order=order, shared=shared_tau)
    idx = jnp.clip(jnp.searchsorted(grid_j, tau), 0, len(grid) - 1)
    tau_b = grid_j[idx]                                  # bucketized tau

    def step(x, t, k):
        t_norm = jnp.full((batch,), t / T, jnp.float32)
        logits = denoise_fn(x, t_norm, cond)
        return decode.fused_update(k, logits, x, tau_b, t, noise, cfg,
                                   version=version)

    x = loop.scan_loop(k_loop, grid_j[::-1].astype(jnp.float32), x, step)
    return SamplerOutput(tokens=x, nfe=len(grid),
                         aux={"tau": tau, "grid": grid})


def sample_scan(key: jax.Array, denoise_fn: DenoiseFn, noise: NoiseDist,
                dist: TransitionDist, batch: int, N: int,
                cond=None, cfg: SamplerConfig = SamplerConfig(),
                version: int = 1, order: str = "iid",
                shared_tau: bool = True) -> SamplerOutput:
    """Fully-jitted faithful DNDM: scan over all T steps, ``lax.cond``
    gating the network call.  Counted NFE equals Algorithm 1's."""
    T = dist.T
    tau, x, k_loop = loop.setup(key, noise, batch, N, dist=dist,
                                order=order, shared=shared_tau)

    def step(carry, t, k):
        x, nfe = carry
        has_transition = jnp.any(tau == t.astype(tau.dtype))

        def call(x):
            t_norm = jnp.full((batch,), t / T, jnp.float32)
            logits = denoise_fn(x, t_norm, cond)
            return decode.fused_update(k, logits, x, tau, t, noise, cfg,
                                       version=version)

        x = jax.lax.cond(has_transition, call, lambda x: x, x)
        return (x, nfe + has_transition.astype(jnp.int32))

    ts = jnp.arange(T, 0, -1).astype(jnp.float32)
    x, nfe = loop.scan_loop(k_loop, ts, (x, jnp.asarray(0)), step)
    return SamplerOutput(tokens=x, nfe=int(jax.device_get(nfe)),
                         aux={"tau": tau})
