"""RDM / RDM-k sampling (Zheng et al. 2023) — the paper's main baseline.

Reparameterized reverse sampling: at every step the network is called
(NFE = T), a fresh x0_hat is decoded, and the set of "denoised" tokens is
grown so that the clean fraction tracks alpha_{t-1}:

  * RDM   — the newly denoised tokens are chosen uniformly at random
            among the still-noisy ones (the b_t routing variable);
  * RDM-k — they are the still-noisy tokens with the highest decoding
            scores (the discriminative top-k trick, App. E).

Denoised tokens keep their committed value; noisy tokens are re-noised
(multinomial) or stay [MASK] (absorbing).  Fully jittable.  The
(token, score) pair comes from ``decode.decode_tokens`` — on the
pallas/interpret backends that is the streaming ``decode_scores``
kernel, so RDM's per-step decode never materializes the (B, N, K)
log-softmax.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import decode
from repro.core.noise import NoiseDist
from repro.core.samplers import loop
from repro.core.samplers.base import DenoiseFn, SamplerConfig, SamplerOutput
from repro.core.schedules import Schedule

Array = jnp.ndarray


def sample(key: jax.Array, denoise_fn: DenoiseFn, noise: NoiseDist,
           schedule: Schedule, batch: int, N: int,
           cond=None, cfg: SamplerConfig = SamplerConfig(),
           topk: bool = True) -> SamplerOutput:
    T = schedule.T
    alphas = jnp.asarray(schedule.alphas, jnp.float32)
    _, x, k_loop = loop.setup(key, noise, batch, N)
    denoised = jnp.zeros((batch, N), bool)

    def step(carry, t, k):
        x, denoised = carry
        k_sel, k_route = jax.random.split(k)
        t_norm = jnp.full((batch,), t / T, jnp.float32)
        logits = denoise_fn(x, t_norm, cond)
        x0_hat, score = decode.decode_tokens(k_sel, logits, noise, cfg)
        # target number of clean tokens after this step: N * (1 - ?) —
        # clean fraction at time t-1 is alpha_{t-1} (forward marginal).
        k_target = jnp.round(N * alphas[t - 1]).astype(jnp.int32)
        k_target = jnp.maximum(k_target, denoised.sum(-1))  # never shrink
        if topk:
            s = jnp.where(denoised, jnp.inf, score)
        else:
            s = jnp.where(denoised, jnp.inf,
                          jax.random.uniform(k_route, score.shape))
        order = jnp.argsort(-s, axis=-1)
        ranks = jnp.argsort(order, axis=-1)
        in_top = ranks < k_target[..., None]
        newly = in_top & ~denoised
        x = jnp.where(newly, x0_hat, x)
        return (x, denoised | newly)

    ts = jnp.arange(T, 0, -1)
    x, denoised = loop.scan_loop(k_loop, ts, (x, denoised), step)
    return SamplerOutput(tokens=x, nfe=T, aux={})
