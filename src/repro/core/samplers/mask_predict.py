"""Mask-Predict baseline (Ghazvininejad et al. 2019; paper App. G.2).

Iterative refinement with a fixed iteration budget M: start all-[MASK],
predict every position each round, keep the most confident tokens and
re-mask the rest on a linear-decay schedule n_i = N * (M - i) / M.
NFE = M.  Absorbing-vocabulary models only (needs a [MASK] id).
Confidence is the per-token score from ``decode.decode_tokens`` (the
streaming ``decode_scores`` kernel on the pallas/interpret backends).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import decode
from repro.core.noise import NoiseDist
from repro.core.samplers import loop
from repro.core.samplers.base import DenoiseFn, SamplerConfig, SamplerOutput

Array = jnp.ndarray


def sample(key: jax.Array, denoise_fn: DenoiseFn, noise: NoiseDist,
           iterations: int, batch: int, N: int,
           cond=None, cfg: SamplerConfig = SamplerConfig()) -> SamplerOutput:
    if noise.kind != "absorbing":
        raise ValueError("Mask-Predict needs an absorbing ([MASK]) vocab")
    mask_id = noise.mask_id
    # absorbing q_noise IS the all-[MASK] start state
    _, x, k_loop = loop.setup(key, noise, batch, N)
    M = iterations

    def step(carry, i, k):
        x, _ = carry
        t_norm = jnp.full((batch,), (M - i) / M, jnp.float32)
        logits = denoise_fn(x, t_norm, cond)
        x0_hat, score = decode.decode_tokens(k, logits, noise, cfg)
        n_mask = jnp.round(N * (M - 1 - i) / M).astype(jnp.int32)  # to re-mask
        order = jnp.argsort(score, axis=-1)          # ascending confidence
        ranks = jnp.argsort(order, axis=-1)
        remask = ranks < n_mask
        x = jnp.where(remask, mask_id, x0_hat)
        return (x.astype(jnp.int32), score)

    x, _ = loop.scan_loop(k_loop, jnp.arange(M),
                          (x, jnp.zeros((batch, N))), step)
    return SamplerOutput(tokens=x, nfe=M, aux={})
