"""Call-schedule-as-data + row-resumable sampler steps (serving substrate).

DNDM's headline structural property (Thm 3.6 / Alg. 2) is that the whole
schedule of network calls is knowable *before* sampling starts: sample
the transition-time set tau at admission and the request's unique-time
walk, its per-step PRNG keys and its x_T draw are all determined.  This
module reifies that as data:

* :class:`CallSchedule` — one request's predetermined call schedule
  (descending times, per-call key stream, tau set, x_T), produced by a
  per-method ``schedule_fn(key, rt, N)`` registered on the sampler spec.
  Every plan replays the solo sampler's ``loop.setup`` key-split
  discipline for a batch of one, so a request admitted into a rolling
  batch replays exactly the solo run's randomness.  Grid baselines
  (d3pm / rdm / mask_predict / ddim) have a data-independent times list
  but still carry their own (x_T, key stream); the static DNDM variants
  additionally carry the quantile-bucketized tau.
* batched **row steps** — jitted step functions that advance every live
  row of a rolling batch by one entry of *its own* schedule, at its own
  diffusion time (the denoiser takes per-row ``t_norm``), with its own
  per-row Gumbel/uniform/Bernoulli slab.  This is what lets
  ``ContinuousScheduler`` admit mid-flight and skip the no-op steps a
  drain batch would pay for — for *every* registered method, not just
  the DNDM family.

Bitwise parity with the solo path rests on three audited contracts:
``decode_tokens`` and ``fused_update`` share the token-selection
pre-activation (``adjust_logits`` op order, see kernels/dndm_update);
``jax.random`` draws broadcast over a leading batch=1 axis
(``gumbel(k, (1, N, K)) == gumbel(k, (N, K))``, same for uniform /
bernoulli, and ``categorical(k, logits) == argmax(gumbel(k,
logits.shape, logits.dtype) + logits)``) under the threefry counter
grid; and the per-row ``t/T`` normalization is the same f32 device
division the solo step performs.

Free/padded rows are parked at a sentinel time outside the schedule
(``T + 1`` on a discrete grid, ``2.0`` in continuous time); every row
step gates its update on ``live = 1 <= t <= T`` (``t <= 1.0``
continuous) so a free row passes through bit-unchanged no matter what
the shared network call computed for it.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import decode
from repro.core.posterior import posterior
from repro.core.samplers import loop
from repro.core.samplers.dndm import quantile_grid
from repro.core.samplers.dndm_topk import _reveal_topk

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class CallSchedule:
    """One request's predetermined network-call schedule.

    ``times`` is the descending sequence of diffusion times at which the
    request calls the network — for Algorithm 1/4 the unique values of
    its tau set, for the static/baseline methods the compiled grid, for
    Algorithm 2 the request's own continuous timestamps.
    ``steps_skipped`` counts the no-op grid steps the predetermined
    schedule proves it never has to pay for (T - |times|; 0 for
    continuous-time schedules, where the grid is the request itself).
    ``tau`` is None for the schedule-driven baselines (their update rule
    never consults a transition-time set).

    ``request_id`` is the serving-layer trace identity: the scheduler
    stamps the id minted at ``submit()`` onto the plan
    (``dataclasses.replace``), and every batched ``engine.stepwise``
    span lists the ids of the rows it advanced — which is what makes a
    request's full call timeline reconstructable from one trace file
    (``obs.timeline``).  ``schedule_fn`` implementations leave it None.
    """

    times: np.ndarray                    # descending call times
    T: int                               # grid size (0 => continuous)
    tau: np.ndarray | None = None        # (N,) per-token transition times
    x0: np.ndarray | None = None         # (N,) the request's x_T draw
    step_keys: np.ndarray | None = None  # (len(times), 2) per-call keys
    request_id: str | None = None        # trace identity (scheduler-set)

    @property
    def nfe(self) -> int:
        return len(self.times)

    @property
    def steps_executed(self) -> int:
        return len(self.times)

    @property
    def steps_skipped(self) -> int:
        return max(self.T - len(self.times), 0) if self.T else 0


# ------------------------------------------------------------------
# schedule_fn per method family: (key, rt, N) -> CallSchedule
# ------------------------------------------------------------------

def dndm_plan(key: jax.Array, rt, N: int) -> CallSchedule:
    """Admission plan for the host-driven DNDM family (Alg. 1/3/4).

    Replays ``loop.setup`` for a batch of one under the request's key, so
    (tau, x_T, per-step keys) are bit-identical to what the solo sampler
    would draw — the scheduler's solo-parity guarantee starts here.
    """
    tau, x, k_loop = loop.setup(key, rt.noise, 1, N, dist=rt.dist,
                                order=rt.order, shared=rt.shared_tau)
    tau_row = np.asarray(jax.device_get(tau))[0]
    times = loop.unique_times(tau_row)
    step_keys = np.asarray(jax.random.split(k_loop, len(times)))
    return CallSchedule(times=times, T=rt.dist.T, tau=tau_row,
                        x0=np.asarray(jax.device_get(x))[0],
                        step_keys=step_keys)


def static_grid_plan(key: jax.Array, rt, N: int) -> CallSchedule:
    """dndm_static / dndm_topk_static: the (deduped) quantile grid, fixed
    NFE, the request's own tau bucketized onto it exactly as the solo
    scan does (``searchsorted`` up to the nearest grid time)."""
    from repro.core.samplers.registry import resolved_budget
    grid = np.asarray(quantile_grid(rt.dist, resolved_budget(rt, N)))
    tau, x, k_loop = loop.setup(key, rt.noise, 1, N, dist=rt.dist,
                                order=rt.order, shared=rt.shared_tau)
    tau_row = np.asarray(jax.device_get(tau))[0]
    idx = np.clip(np.searchsorted(grid, tau_row), 0, len(grid) - 1)
    step_keys = np.asarray(jax.random.split(k_loop, len(grid)))
    return CallSchedule(times=grid[::-1], T=rt.dist.T,
                        tau=grid[idx].astype(np.int32),
                        x0=np.asarray(jax.device_get(x))[0],
                        step_keys=step_keys)


def full_grid_plan(key: jax.Array, rt, N: int) -> CallSchedule:
    """Ancestral baselines (d3pm, rdm, rdm_k, mask_predict): every step.

    No transition-time set (``tau=None``) — the times are the whole grid
    — but (x_T, per-step keys) still replay the solo ``loop.setup`` /
    ``scan_loop`` streams for a batch of one.
    """
    _, x, k_loop = loop.setup(key, rt.noise, 1, N)
    times = np.arange(rt.steps, 0, -1)
    step_keys = np.asarray(jax.random.split(k_loop, len(times)))
    return CallSchedule(times=times, T=rt.steps,
                        x0=np.asarray(jax.device_get(x))[0],
                        step_keys=step_keys)


def ddim_grid_plan(key: jax.Array, rt, N: int) -> CallSchedule:
    """DDIM subsequence grid: ceil(T / stride) calls."""
    _, x, k_loop = loop.setup(key, rt.noise, 1, N)
    times = np.arange(rt.steps, 0, -rt.ddim_stride)
    step_keys = np.asarray(jax.random.split(k_loop, len(times)))
    return CallSchedule(times=times, T=rt.steps,
                        x0=np.asarray(jax.device_get(x))[0],
                        step_keys=step_keys)


def continuous_plan(key: jax.Array, rt, N: int) -> CallSchedule:
    """DNDM-C: N continuous timestamps, each its own call (NFE = N)."""
    tau, x, k_loop = loop.setup(key, rt.noise, 1, N, dist=rt.cdist,
                                order=rt.order, shared=rt.shared_tau,
                                continuous=True)
    row = np.asarray(jax.device_get(tau))[0]
    step_keys = np.asarray(jax.random.split(k_loop, N))
    return CallSchedule(times=np.sort(row)[::-1], T=0, tau=row,
                        x0=np.asarray(jax.device_get(x))[0],
                        step_keys=step_keys)


# ------------------------------------------------------------------
# batched row steps: advance every live row by one own-schedule entry
# ------------------------------------------------------------------

def _row_gumbel(keys: Array, shape, x0_mode: str) -> Array | None:
    """Per-row Gumbel slab: row b drawn from keys[b] alone, bit-identical
    to the (1, N, K) slab the solo batch-of-one step draws from that key."""
    if x0_mode == "argmax":
        return None
    return jax.vmap(lambda k: jax.random.gumbel(k, shape[1:],
                                                jnp.float32))(keys)


def _row_split(keys: Array) -> tuple[Array, Array]:
    """Per-row ``jax.random.split``: the row steps that consume two
    streams per call (rdm routing, ddim keep-mask) split each row's key
    exactly as the solo scan body splits its step key."""
    ks = jax.vmap(lambda k: jax.random.split(k))(keys)
    return ks[:, 0], ks[:, 1]


def _live(t_row: Array, T: int) -> Array:
    """Row liveness on a discrete grid: the free-row sentinel T+1 (and
    anything else outside [1, T]) must never mutate its row."""
    return (t_row >= 1) & (t_row <= T)


@partial(jax.jit, static_argnames=("denoise_fn", "noise", "cfg", "version",
                                   "T"))
def _dndm_rows(x, tau, t_row, keys, cond, *, denoise_fn, noise, cfg,
               version, T):
    """One batched network call, each row at its own time t_row[b].

    Token selection goes through ``decode_tokens`` (bitwise-identical to
    the fused kernel's argmax by the shared pre-activation contract) and
    the eq. (9) update is applied per row against its own tau set.  Rows
    whose tau has no entry at t_row[b] (including free/padded rows) pass
    through unchanged under version 1.
    """
    t_norm = t_row.astype(jnp.float32) / T
    logits = denoise_fn(x, t_norm, cond)
    g = _row_gumbel(keys, logits.shape, cfg.x0_mode)
    x0_hat, _ = decode.decode_tokens(None, logits, noise, cfg, gumbel=g)
    tcol = t_row[:, None].astype(tau.dtype)
    sel = (tau == tcol) if version == 1 else (tau >= tcol)
    sel = sel & _live(t_row, T)[:, None]
    return jnp.where(sel, x0_hat, x)


@partial(jax.jit, static_argnames=("denoise_fn", "noise", "cfg", "T"))
def _dndm_topk_rows(x, revealed, tau, t_row, keys, cond, *, denoise_fn,
                    noise, cfg, T):
    """Algorithm 4's confidence-ranked reveal, row-resumable: K_t is
    computed per row from that row's tau against that row's time."""
    t_norm = t_row.astype(jnp.float32) / T
    logits = denoise_fn(x, t_norm, cond)
    g = _row_gumbel(keys, logits.shape, cfg.x0_mode)
    x0_hat, score = decode.decode_tokens(None, logits, noise, cfg, gumbel=g)
    k_target = jnp.sum(tau >= t_row[:, None].astype(tau.dtype), axis=-1)
    k_target = jnp.where(_live(t_row, T), k_target, 0)
    return _reveal_topk(x, x0_hat, score, revealed, k_target)


@partial(jax.jit, static_argnames=("denoise_fn", "noise", "cfg", "T"))
def _d3pm_rows(x, t_row, keys, cond, alphas, *, denoise_fn, noise, cfg, T):
    """D3PM ancestral step, row-resumable: per-row (alpha_{t-1}, alpha_t)
    gather and a per-row Gumbel-max categorical draw — the same sample
    ``jax.random.categorical(step_key, log p)`` produces for a batch of
    one (categorical == argmax(gumbel + logits), and the (1, N, K)
    Gumbel slab equals the (N, K) slab under the row's key)."""
    t_norm = t_row.astype(jnp.float32) / T
    logits = denoise_fn(x, t_norm, cond) + noise.logit_mask()
    x0_probs = jax.nn.softmax(logits / cfg.temperature, axis=-1)
    # sentinel rows gather alphas[T] / clipped alphas[T+1->T]: harmless,
    # their sampled values are discarded by the live gate below
    a_tm1 = alphas[t_row - 1][:, None]
    a_t = alphas[t_row][:, None]
    p = posterior(x, x0_probs, a_tm1, a_t, noise)
    logp = jnp.log(p + 1e-30)
    g = jax.vmap(lambda k: jax.random.gumbel(k, logp.shape[1:],
                                             logp.dtype))(keys)
    x_new = jnp.argmax(logp + g, axis=-1).astype(jnp.int32)
    return jnp.where(_live(t_row, T)[:, None], x_new, x)


@partial(jax.jit, static_argnames=("denoise_fn", "noise", "cfg", "topk",
                                   "T"))
def _rdm_rows(x, denoised, t_row, keys, cond, alphas, *, denoise_fn, noise,
              cfg, topk, T):
    """RDM / RDM-k step, row-resumable: per-row clean-fraction target
    ``round(N * alpha_{t-1})`` and per-row routing noise (uniform slab
    from the row's k_route for RDM; the row's own scores for RDM-k)."""
    N = x.shape[1]
    k_sel, k_route = _row_split(keys)
    t_norm = t_row.astype(jnp.float32) / T
    logits = denoise_fn(x, t_norm, cond)
    g = _row_gumbel(k_sel, logits.shape, cfg.x0_mode)
    x0_hat, score = decode.decode_tokens(None, logits, noise, cfg, gumbel=g)
    k_target = jnp.round(N * alphas[t_row - 1]).astype(jnp.int32)
    k_target = jnp.maximum(k_target, denoised.sum(-1))  # never shrink
    if topk:
        s = jnp.where(denoised, jnp.inf, score)
    else:
        u = jax.vmap(lambda k: jax.random.uniform(k, (N,)))(k_route)
        s = jnp.where(denoised, jnp.inf, u)
    order = jnp.argsort(-s, axis=-1)
    ranks = jnp.argsort(order, axis=-1)
    in_top = ranks < k_target[..., None]
    newly = in_top & ~denoised & _live(t_row, T)[:, None]
    return jnp.where(newly, x0_hat, x), denoised | newly


@partial(jax.jit, static_argnames=("denoise_fn", "noise", "cfg", "M"))
def _mask_predict_rows(x, t_row, keys, cond, *, denoise_fn, noise, cfg, M):
    """Mask-Predict round, row-resumable.  The solo scan iterates
    ``i = 0..M-1`` with ``t_norm = (M - i) / M``; a row at grid time t
    (descending M..1) is at iteration ``i = M - t``, so the re-mask
    budget ``N * (M - 1 - i) / M`` becomes ``N * (t - 1) / M``."""
    N = x.shape[1]
    t_norm = t_row.astype(jnp.float32) / M
    logits = denoise_fn(x, t_norm, cond)
    g = _row_gumbel(keys, logits.shape, cfg.x0_mode)
    x0_hat, score = decode.decode_tokens(None, logits, noise, cfg, gumbel=g)
    n_mask = jnp.round(N * (t_row - 1) / M).astype(jnp.int32)
    order = jnp.argsort(score, axis=-1)          # ascending confidence
    ranks = jnp.argsort(order, axis=-1)
    remask = ranks < n_mask[:, None]
    x_new = jnp.where(remask, noise.mask_id, x0_hat).astype(jnp.int32)
    return jnp.where(_live(t_row, M)[:, None], x_new, x)


@partial(jax.jit, static_argnames=("denoise_fn", "noise", "cfg", "stride",
                                   "T"))
def _ddim_rows(x, t_row, keys, cond, alphas, *, denoise_fn, noise, cfg,
               stride, T):
    """Discrete-DDIM step, row-resumable: per-row sigma_t from the row's
    (t, t - stride) pair and a per-row Bernoulli keep-mask drawn from the
    row's k_jump — the stochastic per-step draw Remark 3.5 contrasts
    with DNDM's predetermined times."""
    N = x.shape[1]
    k_sel, k_jump = _row_split(keys)
    t_norm = t_row.astype(jnp.float32) / T
    logits = denoise_fn(x, t_norm, cond)
    g = _row_gumbel(k_sel, logits.shape, cfg.x0_mode)
    x0_hat, _ = decode.decode_tokens(None, logits, noise, cfg, gumbel=g)
    t_prev = jnp.maximum(t_row - stride, 0)
    a_prev, a_t = alphas[t_prev], alphas[t_row]
    sigma = (1.0 - a_prev) / jnp.maximum(1.0 - a_t, 1e-9)
    keep = jax.vmap(
        lambda k, p: jax.random.bernoulli(k, p, (N,)))(
            k_jump, jnp.clip(sigma, 0, 1))
    x_new = jnp.where(keep, x, x0_hat).astype(jnp.int32)
    return jnp.where(_live(t_row, T)[:, None], x_new, x)


@partial(jax.jit, static_argnames=("denoise_fn", "noise", "cfg", "topk"))
def _dndm_c_rows(x, revealed, tau, t_row, keys, cond, *, denoise_fn, noise,
                 cfg, topk):
    """Algorithm 2 step, row-resumable in continuous time: t_row *is* the
    row's current timestamp (passed to the denoiser raw, as the solo scan
    does).  The revealed token is the one owning the timestamp
    (``tau == t``; timestamps are a.s. distinct) or the top-score
    unrevealed one for the top-k variant.  Free rows park at the
    sentinel 2.0 > 1 and are gated out."""
    live = t_row <= 1.0
    logits = denoise_fn(x, t_row, cond)
    g = _row_gumbel(keys, logits.shape, cfg.x0_mode)
    x0_hat, score = decode.decode_tokens(None, logits, noise, cfg, gumbel=g)
    if topk:
        s = jnp.where(revealed, -jnp.inf, score)
        upd = jax.nn.one_hot(s.argmax(-1), x.shape[1], dtype=bool)
    else:
        upd = tau == t_row[:, None]
    upd = upd & live[:, None]
    return jnp.where(upd, x0_hat, x), revealed | upd


# ------------------------------------------------------------------
# stepwise_step wrappers: (state, tau, t_row, keys, cond, rt) -> state
# ------------------------------------------------------------------

def dndm_stepwise(version: int):
    """stepwise_step for dndm / dndm_static (version=1), dndm2 (2)."""
    def step(state: dict, tau, t_row, keys, cond, rt) -> dict:
        x = _dndm_rows(state["x"], tau, t_row, keys, cond,
                       denoise_fn=rt.denoise_fn, noise=rt.noise, cfg=rt.cfg,
                       version=version, T=rt.dist.T)
        return {"x": x, "revealed": state["revealed"]}
    return step


def dndm_topk_stepwise(state: dict, tau, t_row, keys, cond, rt) -> dict:
    x, revealed = _dndm_topk_rows(state["x"], state["revealed"], tau, t_row,
                                  keys, cond, denoise_fn=rt.denoise_fn,
                                  noise=rt.noise, cfg=rt.cfg, T=rt.dist.T)
    return {"x": x, "revealed": revealed}


def _alphas(rt) -> Array:
    return jnp.asarray(rt.schedule.alphas, jnp.float32)


def d3pm_stepwise(state: dict, tau, t_row, keys, cond, rt) -> dict:
    x = _d3pm_rows(state["x"], t_row, keys, cond, _alphas(rt),
                   denoise_fn=rt.denoise_fn, noise=rt.noise, cfg=rt.cfg,
                   T=rt.steps)
    return {"x": x, "revealed": state["revealed"]}


def rdm_stepwise(topk: bool):
    """stepwise_step for rdm (topk=False) / rdm_k (topk=True); the
    ``revealed`` buffer carries RDM's denoised set."""
    def step(state: dict, tau, t_row, keys, cond, rt) -> dict:
        x, denoised = _rdm_rows(state["x"], state["revealed"], t_row, keys,
                                cond, _alphas(rt), denoise_fn=rt.denoise_fn,
                                noise=rt.noise, cfg=rt.cfg, topk=topk,
                                T=rt.steps)
        return {"x": x, "revealed": denoised}
    return step


def mask_predict_stepwise(state: dict, tau, t_row, keys, cond, rt) -> dict:
    x = _mask_predict_rows(state["x"], t_row, keys, cond,
                           denoise_fn=rt.denoise_fn, noise=rt.noise,
                           cfg=rt.cfg, M=rt.steps)
    return {"x": x, "revealed": state["revealed"]}


def ddim_stepwise(state: dict, tau, t_row, keys, cond, rt) -> dict:
    x = _ddim_rows(state["x"], t_row, keys, cond, _alphas(rt),
                   denoise_fn=rt.denoise_fn, noise=rt.noise, cfg=rt.cfg,
                   stride=rt.ddim_stride, T=rt.steps)
    return {"x": x, "revealed": state["revealed"]}


def dndm_c_stepwise(topk: bool):
    """stepwise_step for dndm_c / dndm_c_topk (continuous time)."""
    def step(state: dict, tau, t_row, keys, cond, rt) -> dict:
        x, revealed = _dndm_c_rows(state["x"], state["revealed"], tau,
                                   t_row, keys, cond,
                                   denoise_fn=rt.denoise_fn, noise=rt.noise,
                                   cfg=rt.cfg, topk=topk)
        return {"x": x, "revealed": revealed}
    return step
