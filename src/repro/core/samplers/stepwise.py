"""Call-schedule-as-data + row-resumable DNDM steps (serving substrate).

DNDM's headline structural property (Thm 3.6 / Alg. 2) is that the whole
schedule of network calls is knowable *before* sampling starts: sample
the transition-time set tau at admission and the request's unique-time
walk, its per-step PRNG keys and its x_T draw are all determined.  This
module reifies that as data:

* :class:`CallSchedule` — one request's predetermined call schedule
  (descending times, per-call key stream, tau set, x_T), produced by a
  per-method ``schedule_fn(key, rt, N)`` registered on the sampler spec.
  For the host-driven DNDM family the plan reuses ``loop.setup`` with the
  *same* key-split discipline as the solo samplers, so a request admitted
  into a rolling batch replays exactly the solo run's randomness.
* batched **row steps** — jitted step functions that advance every live
  row of a rolling batch by one entry of *its own* schedule, at its own
  diffusion time (the denoiser takes per-row ``t_norm``), with its own
  per-row Gumbel slab.  This is what lets ``ContinuousScheduler`` admit
  mid-flight and skip the no-op steps a drain batch would pay for.

Bitwise parity with the solo path rests on three audited contracts:
``decode_tokens`` and ``fused_update`` share the token-selection
pre-activation (``adjust_logits`` op order, see kernels/dndm_update);
``jax.random.gumbel(k, (1, N, K))`` equals ``gumbel(k, (N, K))`` under
broadcasting of the threefry counter grid; and the per-row ``t/T``
normalization is the same f32 device division the solo step performs.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import decode
from repro.core.samplers import loop
from repro.core.samplers.dndm import quantile_grid
from repro.core.samplers.dndm_topk import _reveal_topk

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class CallSchedule:
    """One request's predetermined network-call schedule.

    ``times`` is the descending sequence of diffusion times at which the
    request calls the network — for Algorithm 1/4 the unique values of
    its tau set, for the static/baseline methods the compiled grid.
    ``steps_skipped`` counts the no-op grid steps the predetermined
    schedule proves it never has to pay for (T - |times|; 0 for
    continuous-time schedules, where the grid is the request itself).
    """

    times: np.ndarray                    # descending call times
    T: int                               # grid size (0 => continuous)
    tau: np.ndarray | None = None        # (N,) per-token transition times
    x0: np.ndarray | None = None         # (N,) the request's x_T draw
    step_keys: np.ndarray | None = None  # (len(times), 2) per-call keys

    @property
    def nfe(self) -> int:
        return len(self.times)

    @property
    def steps_executed(self) -> int:
        return len(self.times)

    @property
    def steps_skipped(self) -> int:
        return max(self.T - len(self.times), 0) if self.T else 0


# ------------------------------------------------------------------
# schedule_fn per method family: (key, rt, N) -> CallSchedule
# ------------------------------------------------------------------

def dndm_plan(key: jax.Array, rt, N: int) -> CallSchedule:
    """Admission plan for the host-driven DNDM family (Alg. 1/3/4).

    Replays ``loop.setup`` for a batch of one under the request's key, so
    (tau, x_T, per-step keys) are bit-identical to what the solo sampler
    would draw — the scheduler's solo-parity guarantee starts here.
    """
    tau, x, k_loop = loop.setup(key, rt.noise, 1, N, dist=rt.dist,
                                order=rt.order, shared=rt.shared_tau)
    tau_row = np.asarray(jax.device_get(tau))[0]
    times = loop.unique_times(tau_row)
    step_keys = np.asarray(jax.random.split(k_loop, len(times)))
    return CallSchedule(times=times, T=rt.dist.T, tau=tau_row,
                        x0=np.asarray(jax.device_get(x))[0],
                        step_keys=step_keys)


def static_grid_plan(key: jax.Array, rt, N: int) -> CallSchedule:
    """dndm_static / dndm_topk_static: the quantile grid, fixed NFE."""
    from repro.core.samplers.registry import resolved_budget
    grid = quantile_grid(rt.dist, resolved_budget(rt, N))
    return CallSchedule(times=np.asarray(grid)[::-1], T=rt.dist.T)


def full_grid_plan(key: jax.Array, rt, N: int) -> CallSchedule:
    """Ancestral baselines (d3pm, rdm, rdm_k, mask_predict): every step."""
    return CallSchedule(times=np.arange(rt.steps, 0, -1), T=rt.steps)


def ddim_grid_plan(key: jax.Array, rt, N: int) -> CallSchedule:
    """DDIM subsequence grid: ceil(T / stride) calls."""
    return CallSchedule(times=np.arange(rt.steps, 0, -rt.ddim_stride),
                        T=rt.steps)


def continuous_plan(key: jax.Array, rt, N: int) -> CallSchedule:
    """DNDM-C: N continuous timestamps, each its own call (NFE = N)."""
    tau, _, _ = loop.setup(key, rt.noise, 1, N, dist=rt.cdist,
                           order=rt.order, shared=rt.shared_tau,
                           continuous=True)
    row = np.asarray(jax.device_get(tau))[0]
    return CallSchedule(times=np.sort(row)[::-1], T=0, tau=row)


# ------------------------------------------------------------------
# batched row steps: advance every live row by one own-schedule entry
# ------------------------------------------------------------------

def _row_gumbel(keys: Array, shape, x0_mode: str) -> Array | None:
    """Per-row Gumbel slab: row b drawn from keys[b] alone, bit-identical
    to the (1, N, K) slab the solo batch-of-one step draws from that key."""
    if x0_mode == "argmax":
        return None
    return jax.vmap(lambda k: jax.random.gumbel(k, shape[1:],
                                                jnp.float32))(keys)


@partial(jax.jit, static_argnames=("denoise_fn", "noise", "cfg", "version",
                                   "T"))
def _dndm_rows(x, tau, t_row, keys, cond, *, denoise_fn, noise, cfg,
               version, T):
    """One batched network call, each row at its own time t_row[b].

    Token selection goes through ``decode_tokens`` (bitwise-identical to
    the fused kernel's argmax by the shared pre-activation contract) and
    the eq. (9) update is applied per row against its own tau set.  Rows
    whose tau has no entry at t_row[b] (including free/padded rows) pass
    through unchanged under version 1.
    """
    t_norm = t_row.astype(jnp.float32) / T
    logits = denoise_fn(x, t_norm, cond)
    g = _row_gumbel(keys, logits.shape, cfg.x0_mode)
    x0_hat, _ = decode.decode_tokens(None, logits, noise, cfg, gumbel=g)
    tcol = t_row[:, None].astype(tau.dtype)
    sel = (tau == tcol) if version == 1 else (tau >= tcol)
    return jnp.where(sel, x0_hat, x)


@partial(jax.jit, static_argnames=("denoise_fn", "noise", "cfg", "T"))
def _dndm_topk_rows(x, revealed, tau, t_row, keys, cond, *, denoise_fn,
                    noise, cfg, T):
    """Algorithm 4's confidence-ranked reveal, row-resumable: K_t is
    computed per row from that row's tau against that row's time."""
    t_norm = t_row.astype(jnp.float32) / T
    logits = denoise_fn(x, t_norm, cond)
    g = _row_gumbel(keys, logits.shape, cfg.x0_mode)
    x0_hat, score = decode.decode_tokens(None, logits, noise, cfg, gumbel=g)
    k_target = jnp.sum(tau >= t_row[:, None].astype(tau.dtype), axis=-1)
    return _reveal_topk(x, x0_hat, score, revealed, k_target)


def dndm_stepwise(version: int):
    """stepwise_step for dndm (version=1) / dndm2 (version=2)."""
    def step(state: dict, tau, t_row, keys, cond, rt) -> dict:
        x = _dndm_rows(state["x"], tau, t_row, keys, cond,
                       denoise_fn=rt.denoise_fn, noise=rt.noise, cfg=rt.cfg,
                       version=version, T=rt.dist.T)
        return {"x": x, "revealed": state["revealed"]}
    return step


def dndm_topk_stepwise(state: dict, tau, t_row, keys, cond, rt) -> dict:
    x, revealed = _dndm_topk_rows(state["x"], state["revealed"], tau, t_row,
                                  keys, cond, denoise_fn=rt.denoise_fn,
                                  noise=rt.noise, cfg=rt.cfg, T=rt.dist.T)
    return {"x": x, "revealed": revealed}
