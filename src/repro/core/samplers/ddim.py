"""Discrete DDIM baseline (Song et al. 2020a App. A; paper App. B.1).

For multinomial diffusion, the DDIM-style non-Markov posterior is
  q(x_{t-1}|x_t, x0) = Cat(sigma_t x_t + (alpha_{t-1} - sigma_t alpha_t) x0
                           + ((1-alpha_{t-1}) - (1-alpha_t) sigma_t) 1/K)
with the "de-randomized" choice sigma_t = (1-alpha_{t-1})/(1-alpha_t),
under which the uniform term vanishes: x_{t-1} keeps x_t w.p. sigma_t and
jumps to x0_hat w.p. 1-sigma_t.  Crucially (paper Remark 3.5) this stays
*stochastic per step* — unlike DNDM there is no predetermined transition
time, so every step needs a network call.

DDIM's acceleration = running on a subsequence of timesteps (``stride``):
NFE = T/stride.  This gives the matched-NFE comparison DNDM-vs-DDIM that
the paper argues about but does not benchmark.  x0_hat decoding shares
``decode.decode_tokens`` with the confidence-ranked samplers, so DDIM
also rides the streaming decode kernel on the pallas/interpret backends
(the score output is simply unused here).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import decode
from repro.core.noise import NoiseDist
from repro.core.samplers import loop
from repro.core.samplers.base import DenoiseFn, SamplerConfig, SamplerOutput
from repro.core.schedules import Schedule

Array = jnp.ndarray


def sample(key: jax.Array, denoise_fn: DenoiseFn, noise: NoiseDist,
           schedule: Schedule, batch: int, N: int, stride: int = 1,
           cond=None, cfg: SamplerConfig = SamplerConfig()) -> SamplerOutput:
    """DDIM-multinomial on the timestep subsequence {T, T-s, ..., s}."""
    if noise.kind != "multinomial":
        raise ValueError("discrete DDIM baseline is for multinomial "
                         "diffusion (absorbing D3PM is already DDIM-like)")
    T = schedule.T
    alphas = jnp.asarray(schedule.alphas, jnp.float32)
    ts = jnp.arange(T, 0, -stride)              # current times
    ts_prev = jnp.maximum(ts - stride, 0)       # jump targets
    _, x, k_loop = loop.setup(key, noise, batch, N)

    def step(x, t_pair, k):
        t, t_prev = t_pair
        k_sel, k_jump = jax.random.split(k)
        t_norm = jnp.full((batch,), t / T, jnp.float32)
        logits = denoise_fn(x, t_norm, cond)
        x0_hat, _ = decode.decode_tokens(k_sel, logits, noise, cfg)
        a_prev, a_t = alphas[t_prev], alphas[t]
        sigma = (1.0 - a_prev) / jnp.maximum(1.0 - a_t, 1e-9)
        keep = jax.random.bernoulli(k_jump, jnp.clip(sigma, 0, 1),
                                    (batch, N))
        return jnp.where(keep, x, x0_hat).astype(jnp.int32)

    x = loop.scan_loop(k_loop, (ts, ts_prev), x, step)
    return SamplerOutput(tokens=x, nfe=len(ts), aux={})
