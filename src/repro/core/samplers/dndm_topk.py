"""DNDM-K — top-k transition time (paper Algorithm 4, App. E).

Instead of revealing the *specific* tokens whose tau equals t, DNDM-K only
uses the transition times to decide *how many* tokens should be revealed by
step t (``K_t = sum_n 1(tau_n >= t)``), and picks *which* tokens by the
network's own confidence scores (log-prob of the decoded token), never
re-updating an already-revealed token.  Function evaluations happen only
when ``K_{t-1} > K_t`` — the same skip set as Algorithm 1, so the NFE is
identical while quality improves by 1-2 BLEU in the paper.

The per-step (token, score) pair comes from ``decode.decode_tokens``,
which on the pallas/interpret backends is the streaming ``decode_scores``
kernel (no (B, N, K) log-softmax in HBM).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import decode
from repro.core.noise import NoiseDist
from repro.core.samplers import loop
from repro.core.samplers.base import DenoiseFn, SamplerConfig, SamplerOutput
from repro.core.transition import TransitionDist

Array = jnp.ndarray


def _reveal_topk(x: Array, x0_hat: Array, score: Array, revealed: Array,
                 k_target: Array) -> tuple[Array, Array]:
    """Reveal enough top-score tokens to reach k_target revealed per row.

    Already-revealed tokens are pinned with +inf so the top-``k_target``
    set always contains them (Algorithm 4's set U); their values are kept.
    """
    s = jnp.where(revealed, jnp.inf, score)
    # rank within row: position of each token when sorted by descending s
    order = jnp.argsort(-s, axis=-1)
    ranks = jnp.argsort(order, axis=-1)
    in_top = ranks < k_target[:, None]
    newly = in_top & ~revealed
    x = jnp.where(newly, x0_hat, x)
    return x, revealed | newly


@partial(jax.jit, static_argnames=("denoise_fn", "noise", "cfg", "T"))
def _step(x, revealed, t, k_target, k, cond, *, denoise_fn, noise, cfg, T):
    t_norm = jnp.full((x.shape[0],), t / T, jnp.float32)
    logits = denoise_fn(x, t_norm, cond)
    x0_hat, score = decode.decode_tokens(k, logits, noise, cfg)
    return _reveal_topk(x, x0_hat, score, revealed, k_target)


def sample(key: jax.Array, denoise_fn: DenoiseFn, noise: NoiseDist,
           dist: TransitionDist, batch: int, N: int,
           cond=None, cfg: SamplerConfig = SamplerConfig(),
           order: str = "iid", shared_tau: bool = True) -> SamplerOutput:
    """Algorithm 4 — host-driven, NFE = |T| as in Algorithm 1."""
    T = dist.T
    tau, x, k_loop = loop.setup(key, noise, batch, N, dist=dist,
                                order=order, shared=shared_tau)
    revealed = jnp.zeros((batch, N), bool)

    tau_np = np.asarray(jax.device_get(tau))
    times = loop.unique_times(tau_np)                         # descending

    aux = {"tau": tau, "times": times}
    step_attrs = None
    if obs.enabled():
        # reveal counts: Algorithm 4 reveals *as many* tokens per step as
        # Algorithm 1 would (K_{t-1} - K_t), i.e. #(tau == t)
        reveals = loop.reveal_series(tau_np, times, version=1)
        aux["reveal_counts"] = reveals
        hist = obs.histogram("sampler.reveal_count",
                             "tokens revealed per network call (|R_t|)")
        for r in reveals:
            hist.observe(float(r), sampler="dndm_topk", version=1)
        step_attrs = lambda i, t: {"reveal": float(reveals[i])}  # noqa: E731

    def step(carry, t, k):
        x, revealed = carry
        # K_{t-1} = #{n : tau_n >= t} — tokens that must be revealed once
        # the reverse process has passed step t (computed on device).
        k_target = jnp.sum(tau >= int(t), axis=-1)
        return _step(x, revealed, jnp.asarray(t, jnp.float32), k_target, k,
                     cond, denoise_fn=denoise_fn, noise=noise, cfg=cfg, T=T)

    x, revealed = loop.host_loop(k_loop, times, (x, revealed), step,
                                 step_attrs=step_attrs)
    return SamplerOutput(tokens=x, nfe=len(times), aux=aux)


def sample_static(key: jax.Array, denoise_fn: DenoiseFn, noise: NoiseDist,
                  dist: TransitionDist, batch: int, N: int,
                  nfe_budget: int, cond=None,
                  cfg: SamplerConfig = SamplerConfig(),
                  order: str = "iid", shared_tau: bool = True) -> SamplerOutput:
    """Beyond-paper jitted DNDM-K: reveal-count schedule on the quantile
    grid, one compiled ``lax.scan`` with fixed NFE."""
    from repro.core.samplers.dndm import quantile_grid
    T = dist.T
    grid = jnp.asarray(quantile_grid(dist, nfe_budget))

    tau, x, k_loop = loop.setup(key, noise, batch, N, dist=dist,
                                order=order, shared=shared_tau)
    # bucketize up to the grid so the last scanned time covers every token
    idx = jnp.clip(jnp.searchsorted(grid, tau), 0, len(grid) - 1)
    tau_b = grid[idx]
    revealed = jnp.zeros((batch, N), bool)

    def step(carry, t, k):
        x, revealed = carry
        k_target = jnp.sum(tau_b >= t.astype(tau_b.dtype), axis=-1)
        t_norm = jnp.full((batch,), t / T, jnp.float32)
        logits = denoise_fn(x, t_norm, cond)
        x0_hat, score = decode.decode_tokens(k, logits, noise, cfg)
        return _reveal_topk(x, x0_hat, score, revealed, k_target)

    ts = grid[::-1].astype(jnp.float32)
    x, revealed = loop.scan_loop(k_loop, ts, (x, revealed), step)
    # final sweep guarantee: any token still unrevealed gets the last pred
    return SamplerOutput(tokens=x, nfe=len(grid), aux={"tau": tau})
