"""Shared loop skeleton for every reverse-process sampler.

All samplers follow the same outline: split the key, (maybe) draw the
predetermined transition-time set, draw x_T ~ q_noise, then walk time
backwards calling the denoiser.  The walk is either a *host* loop over
the data-dependent unique transition times (faithful Algorithm 1/4) or a
single compiled ``lax.scan`` over a static time grid (the TPU-friendly
variants and all the baselines).  Samplers supply only their per-step
body; tau sampling, x_T init and key threading live here.
"""
from __future__ import annotations

from typing import Any, Callable

import jax

from repro.core.samplers.base import init_noise_tokens
from repro.core.transition import sample_transition_times


def setup(key: jax.Array, noise, batch: int, N: int, *, dist=None,
          order: str = "iid", shared: bool = False,
          continuous: bool = False):
    """The common sampler preamble: returns (tau, x_T, loop_key).

    ``tau`` is None when no transition-time law is given (schedule-driven
    baselines).  The key always splits 3-way in (tau, x_T, loop) order —
    the DNDM family keeps its historical streams; the baselines (which
    used to split 2-way) draw from shifted streams since this skeleton
    landed.
    """
    k_tau, k_x, k_loop = jax.random.split(key, 3)
    tau = None
    if dist is not None:
        tau = sample_transition_times(k_tau, dist, batch, N, order=order,
                                      shared=shared, continuous=continuous)
    x = init_noise_tokens(k_x, noise, batch, N)
    return tau, x, k_loop


def host_loop(key: jax.Array, times, carry, step: Callable,
              on_step: Callable | None = None):
    """Host-driven walk: ``carry = step(carry, t, key_t)`` per time.

    ``times`` is a host-side sequence (the predetermined unique transition
    times, descending); the step itself is expected to be jitted."""
    keys = jax.random.split(key, len(times))
    for i, t in enumerate(times):
        carry = step(carry, t, keys[i])
        if on_step is not None:
            on_step(carry)
    return carry


def scan_loop(key: jax.Array, ts, carry, step: Callable):
    """Compiled walk: one ``lax.scan`` over a static per-step input ``ts``
    (an array, or any pytree of equal-length arrays)."""
    n = jax.tree_util.tree_leaves(ts)[0].shape[0]
    keys = jax.random.split(key, n)

    def body(c, inp):
        t, k = inp
        return step(c, t, k), None

    carry, _ = jax.lax.scan(body, carry, (ts, keys))
    return carry
