"""Shared loop skeleton for every reverse-process sampler.

All samplers follow the same outline: split the key, (maybe) draw the
predetermined transition-time set, draw x_T ~ q_noise, then walk time
backwards calling the denoiser.  The walk is either a *host* loop over
the data-dependent unique transition times (faithful Algorithm 1/4) or a
single compiled ``lax.scan`` over a static time grid (the TPU-friendly
variants and all the baselines).  Samplers supply only their per-step
body; tau sampling, x_T init and key threading live here.

The host loop is the telemetry anchor for DNDM's headline claim: with
``repro.obs`` enabled it records per-step host timing
(``sampler.step_seconds``) and emits one ``sampler.step`` trace event per
network call, carrying whatever the sampler supplies via ``step_attrs``
(the DNDM samplers pass the per-step reveal count |R_t|).  Timing is
host-side dispatch+trace time — steps are *not* blocked on, so enabling
telemetry never adds a device sync.
"""
from __future__ import annotations

import time
from typing import Any, Callable

import jax
import numpy as np

from repro import obs
from repro.core.samplers.base import init_noise_tokens
from repro.core.transition import sample_transition_times


def setup(key: jax.Array, noise, batch: int, N: int, *, dist=None,
          order: str = "iid", shared: bool = False,
          continuous: bool = False):
    """The common sampler preamble: returns (tau, x_T, loop_key).

    ``tau`` is None when no transition-time law is given (schedule-driven
    baselines).  The key always splits 3-way in (tau, x_T, loop) order —
    the DNDM family keeps its historical streams; the baselines (which
    used to split 2-way) draw from shifted streams since this skeleton
    landed.
    """
    k_tau, k_x, k_loop = jax.random.split(key, 3)
    tau = None
    if dist is not None:
        tau = sample_transition_times(k_tau, dist, batch, N, order=order,
                                      shared=shared, continuous=continuous)
    x = init_noise_tokens(k_x, noise, batch, N)
    return tau, x, k_loop


def unique_times(tau) -> np.ndarray:
    """Descending unique transition times of a (host) tau set — the
    predetermined network-call schedule of Algorithm 1/4.  Shared by the
    solo host loops and the admission-time ``CallSchedule`` planner, so
    the serving layer walks *exactly* the times a solo run would."""
    return np.unique(np.asarray(tau))[::-1]


def reveal_series(tau, times, version: int = 1) -> np.ndarray:
    """Per-step reveal counts |R_t| for a host walk over ``times``.

    ``tau`` is the (B, N) transition-time set (host array), ``times`` the
    descending unique times the loop visits.  Version 1 (Algorithm 1)
    reveals the tokens whose tau *equals* t; version 2 (Algorithm 3)
    re-updates every token with tau >= t.  Returns the per-row count
    averaged over the batch, one entry per step — the series DNDM's
    NFE-vs-quality story is about.
    """
    tau = np.asarray(tau)
    times = np.asarray(times).astype(tau.dtype)
    cmp = (tau[..., None] == times) if version == 1 else \
        (tau[..., None] >= times)
    return cmp.sum(axis=-2).mean(axis=0)


def host_loop(key: jax.Array, times, carry, step: Callable,
              on_step: Callable | None = None,
              step_attrs: Callable[[int, Any], dict] | None = None):
    """Host-driven walk: ``carry = step(carry, t, key_t)`` per time.

    ``times`` is a host-side sequence (the predetermined unique transition
    times, descending); the step itself is expected to be jitted.
    ``step_attrs(i, t)`` (optional) supplies extra attributes for the
    per-step trace event when telemetry is enabled — it is never called
    on the disabled path.
    """
    keys = jax.random.split(key, len(times))
    if not obs.enabled():
        for i, t in enumerate(times):
            carry = step(carry, t, keys[i])
            if on_step is not None:
                on_step(carry)
        return carry

    hist = obs.histogram(
        "sampler.step_seconds",
        "host-side dispatch+trace seconds per host-loop step (no sync)")
    for i, t in enumerate(times):
        t0 = time.perf_counter()
        carry = step(carry, t, keys[i])
        dt = time.perf_counter() - t0
        hist.observe(dt, loop="host")
        extra = step_attrs(i, t) if step_attrs is not None else {}
        obs.event("sampler.step", i=i, t=t, dur_s=dt, **extra)
        if on_step is not None:
            on_step(carry)
    return carry


def scan_loop(key: jax.Array, ts, carry, step: Callable):
    """Compiled walk: one ``lax.scan`` over a static per-step input ``ts``
    (an array, or any pytree of equal-length arrays)."""
    n = jax.tree_util.tree_leaves(ts)[0].shape[0]
    keys = jax.random.split(key, n)

    def body(c, inp):
        t, k = inp
        return step(c, t, k), None

    carry, _ = jax.lax.scan(body, carry, (ts, keys))
    return carry
