"""DNDM-C — continuous-time (infinite-step) sampling (paper Algorithm 2).

Transition timestamps are real numbers in (0, 1] drawn from a continuous
D_tau (a.s. all distinct), so the reverse process reveals exactly one token
per network call and NFE = N regardless of how fine the "schedule" is —
the T -> infinity limit of Algorithm 1.

Because the step count is exactly N (static!), DNDM-C is fully jittable as
a single ``lax.scan`` — on TPU this is the most deployment-friendly member
of the family.  A top-k variant mirrors Algorithm 4 in continuous time;
its confidence scores come from ``decode.decode_tokens`` (the streaming
``decode_scores`` kernel on the pallas/interpret backends).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import decode
from repro.core.noise import NoiseDist
from repro.core.samplers import loop
from repro.core.samplers.base import DenoiseFn, SamplerConfig, SamplerOutput
from repro.core.transition import TransitionDist

Array = jnp.ndarray


def sample(key: jax.Array, denoise_fn: DenoiseFn, noise: NoiseDist,
           dist: TransitionDist, batch: int, N: int,
           cond=None, cfg: SamplerConfig = SamplerConfig(),
           topk: bool = False, order: str = "iid",
           shared_tau: bool = False) -> SamplerOutput:
    """Algorithm 2.  One compiled scan of exactly N network calls.

    At scan step k (k = N..1 in paper numbering) the current time is the
    k-th largest timestamp; the token owning that timestamp is revealed
    (``topk=False``) or the highest-score unrevealed token is (``topk=True``,
    the DNDM-k-C variant used in Tables 2/3's infinity rows).
    """
    tau, x, k_loop = loop.setup(key, noise, batch, N, dist=dist,
                                order=order, shared=shared_tau,
                                continuous=True)          # (B, N) float
    revealed = jnp.zeros((batch, N), bool)

    # descending order of timestamps per row; owner[k] = token index
    owner = jnp.argsort(-tau, axis=-1)                          # (B, N)
    tau_sorted = jnp.take_along_axis(tau, owner, axis=-1)       # descending

    def step(carry, k_idx, kk):
        x, revealed = carry
        t_now = tau_sorted[:, k_idx]                            # (B,)
        logits = denoise_fn(x, t_now, cond)
        x0_hat, score = decode.decode_tokens(kk, logits, noise, cfg)
        if topk:
            s = jnp.where(revealed, -jnp.inf, score)
            tok_idx = s.argmax(-1)                              # (B,)
        else:
            tok_idx = owner[:, k_idx]
        upd = jax.nn.one_hot(tok_idx, x.shape[1], dtype=bool)
        x = jnp.where(upd, x0_hat, x)
        revealed = revealed | upd
        return (x, revealed)

    x, revealed = loop.scan_loop(k_loop, jnp.arange(N), (x, revealed), step)
    return SamplerOutput(tokens=x, nfe=N, aux={"tau": tau})
