"""D3PM ancestral sampling — the Markov baseline (paper §2, App. B.1).

One network call per step: NFE = T.  Supports multinomial and absorbing
noise through the shared posterior module.  Fully jittable (lax.scan).
The posterior needs the full x0 probability vector, so this baseline
cannot use the fused argmax decode path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.noise import NoiseDist
from repro.core.posterior import posterior
from repro.core.samplers import loop
from repro.core.samplers.base import DenoiseFn, SamplerConfig, SamplerOutput
from repro.core.schedules import Schedule

Array = jnp.ndarray


def sample(key: jax.Array, denoise_fn: DenoiseFn, noise: NoiseDist,
           schedule: Schedule, batch: int, N: int,
           cond=None, cfg: SamplerConfig = SamplerConfig()) -> SamplerOutput:
    T = schedule.T
    alphas = jnp.asarray(schedule.alphas, jnp.float32)
    _, x, k_loop = loop.setup(key, noise, batch, N)

    def step(x, t, k):
        t_norm = jnp.full((batch,), t / T, jnp.float32)
        logits = denoise_fn(x, t_norm, cond) + noise.logit_mask()
        x0_probs = jax.nn.softmax(logits / cfg.temperature, axis=-1)
        a_tm1 = jnp.full((batch, 1), alphas[t - 1])
        a_t = jnp.full((batch, 1), alphas[t])
        p = posterior(x, x0_probs, a_tm1, a_t, noise)
        x = jax.random.categorical(k, jnp.log(p + 1e-30), axis=-1)
        return x.astype(jnp.int32)

    ts = jnp.arange(T, 0, -1)
    x = loop.scan_loop(k_loop, ts, x, step)
    return SamplerOutput(tokens=x, nfe=T, aux={})
