"""Reverse-process samplers: DNDM family + baselines.

``registry`` maps method names to :class:`~repro.core.samplers.registry.
SamplerSpec` entries — the single source of truth for what can be served;
``loop`` is the shared sampler skeleton.
"""
from repro.core.samplers import (d3pm, ddim, dndm, dndm_continuous,
                                 dndm_topk, loop, mask_predict, rdm,
                                 registry)
from repro.core.samplers.base import (DenoiseFn, SamplerConfig, SamplerOutput,
                                      init_noise_tokens, select_x0)

__all__ = [
    "d3pm", "ddim", "dndm", "dndm_continuous", "dndm_topk", "loop",
    "mask_predict", "rdm", "registry",
    "DenoiseFn", "SamplerConfig", "SamplerOutput", "init_noise_tokens",
    "select_x0",
]
