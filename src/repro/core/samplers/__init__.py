"""Reverse-process samplers: DNDM family + baselines."""
from repro.core.samplers import (d3pm, ddim, dndm, dndm_continuous,
                                 dndm_topk, mask_predict, rdm)
from repro.core.samplers.base import (DenoiseFn, SamplerConfig, SamplerOutput,
                                      init_noise_tokens, select_x0)

__all__ = [
    "d3pm", "ddim", "dndm", "dndm_continuous", "dndm_topk", "mask_predict", "rdm",
    "DenoiseFn", "SamplerConfig", "SamplerOutput", "init_noise_tokens",
    "select_x0",
]
