"""Core discrete-diffusion library: the paper's contribution in JAX.

Modules:
  schedules   — alpha schedules (discrete + continuous limits)
  noise       — multinomial / absorbing q_noise
  forward     — Markov (eq. 1) and non-Markov (eq. 6) corruption
  transition  — transition-time laws, Beta approximation, Thm 3.6/D.1
  posterior   — q(x_{t-1}|x_t, x0) for the D3PM baselines
  losses      — reparameterized CE + ELBO training objectives
  samplers    — DNDM (Alg 1/2/3/4) + D3PM / RDM / Mask-Predict baselines
"""
from repro.core import (forward, losses, noise, posterior, samplers,
                        schedules, transition)

__all__ = ["forward", "losses", "noise", "posterior", "samplers",
           "schedules", "transition"]
