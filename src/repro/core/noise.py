"""Noise distributions ``q_noise`` for discrete diffusion.

The paper covers the two most widely used D3PMs:
  * multinomial diffusion — ``q_noise`` uniform over the vocabulary
    (Hoogeboom et al. 2021b);
  * absorbing diffusion — ``q_noise`` is a point mass on a [MASK] token
    (Austin et al. 2021).

Both are represented by a small object that can sample noise tokens and give
the noise probability vector.  Tokens are integer ids (the one-hot formalism
of the paper is kept in the math, ids in the code).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class NoiseDist:
    """A categorical noise distribution over ``vocab_size`` tokens."""

    kind: str            # "multinomial" | "absorbing"
    vocab_size: int      # includes the mask token for absorbing diffusion
    mask_id: int = -1    # only used by absorbing

    def sample(self, key: jax.Array, shape: tuple[int, ...]) -> Array:
        """Draw noise token ids ``w ~ q_noise``."""
        if self.kind == "multinomial":
            return jax.random.randint(key, shape, 0, self.vocab_size)
        return jnp.full(shape, self.mask_id, dtype=jnp.int32)

    def probs(self, dtype=jnp.float32) -> Array:
        """The row vector ``q_noise`` over the vocabulary."""
        if self.kind == "multinomial":
            return jnp.full((self.vocab_size,), 1.0 / self.vocab_size, dtype)
        return jax.nn.one_hot(self.mask_id, self.vocab_size, dtype=dtype)

    @property
    def pad_id(self) -> int:
        """Token id used to left-pad short conditioning prefixes in a
        mixed-length batch.  Absorbing diffusion has a reserved non-signal
        token — [MASK] — which is the only id a prefix pad may use without
        conditioning the row on spurious content; multinomial has no
        reserved id, so 0 is kept for lack of anything better (documented
        in the scheduler)."""
        if self.kind == "absorbing":
            return self.mask_id
        return 0

    def logit_mask(self, dtype=jnp.float32) -> Array:
        """Additive mask that forbids predicting the noise-only token.

        For absorbing diffusion the network must never predict [MASK] as a
        clean token; multinomial has no reserved ids.
        """
        if self.kind == "absorbing":
            return jnp.where(
                jnp.arange(self.vocab_size) == self.mask_id,
                jnp.asarray(-1e9, dtype), jnp.asarray(0.0, dtype))
        return jnp.zeros((self.vocab_size,), dtype)


def multinomial(vocab_size: int) -> NoiseDist:
    return NoiseDist(kind="multinomial", vocab_size=vocab_size)


def absorbing(vocab_size: int, mask_id: int | None = None) -> NoiseDist:
    """Absorbing noise; by convention [MASK] is the last id unless given."""
    if mask_id is None:
        mask_id = vocab_size - 1
    if not 0 <= mask_id < vocab_size:
        raise ValueError(f"mask_id {mask_id} outside vocab {vocab_size}")
    return NoiseDist(kind="absorbing", vocab_size=vocab_size, mask_id=mask_id)


def get(kind: str, vocab_size: int, mask_id: int | None = None) -> NoiseDist:
    if kind == "multinomial":
        return multinomial(vocab_size)
    if kind == "absorbing":
        return absorbing(vocab_size, mask_id)
    raise KeyError(f"unknown noise kind {kind!r}")
