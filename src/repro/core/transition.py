"""Transition-time machinery — the heart of DNDM.

Definition 3.2: ``tau = min{t : b_t = 0}`` — the (single) step at which a
token flips from clean to noise.  Theorem 3.6: ``P(tau = t) =
alpha_{t-1} - alpha_t``; tokens are independent.  Sampling the whole set
``T = {tau_n}`` *upfront* de-randomizes the reverse process and the NFE is
``|T|`` (unique values), with ``E|T| = (1 - C) T`` (Theorem D.1).

Also implements the practical Beta(a, b) approximation of the transition law
(paper §3.2 / App. C and F) and the position-ordered variants of App. C
Table 6 (left-to-right / right-to-left).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedules import Schedule

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class TransitionDist:
    """Distribution D_tau over {1..T} (discrete) or (0,1] (continuous)."""

    name: str
    T: int                      # 0 => continuous time
    probs: np.ndarray | None    # (T,) for discrete; None for continuous
    beta_params: tuple[float, float] | None = None  # for beta-based laws

    # ---------------- discrete sampling ----------------
    def sample(self, key: jax.Array, shape: tuple[int, ...]) -> Array:
        """Sample integer transition times in {1..T}."""
        if self.T <= 0:
            raise ValueError("discrete sample() on a continuous law")
        if self.probs is not None:
            logits = jnp.log(jnp.asarray(self.probs) + 1e-30)
            return 1 + jax.random.categorical(key, logits, shape=shape)
        a, b = self.beta_params
        u = jax.random.beta(key, a, b, shape)
        return jnp.clip(jnp.round(u * self.T).astype(jnp.int32), 1, self.T)

    # ---------------- continuous sampling ----------------
    def sample_continuous(self, key: jax.Array, shape: tuple[int, ...]) -> Array:
        """Sample real-valued transition times in (0, 1] (DNDM-C)."""
        if self.beta_params is not None:
            a, b = self.beta_params
            return jax.random.beta(key, a, b, shape)
        # inverse-CDF on the discrete grid, then jitter within the bin
        p = jnp.asarray(self.probs)
        k_cat, k_u = jax.random.split(key)
        t = jax.random.categorical(k_cat, jnp.log(p + 1e-30), shape=shape)
        u = jax.random.uniform(k_u, shape)
        return (t.astype(jnp.float32) + u) / self.T

    # ---------------- Theorem D.1 ----------------
    def expected_nfe(self, N: int) -> float:
        """E|T| = [1 - C_{T,N,D}] * T with C = (sum_i (1-p_i)^N) / T."""
        if self.probs is None:
            raise ValueError("expected_nfe needs a discretized law; "
                             "use beta_approx() instead of beta_continuous()")
        p = self.probs.astype(np.float64)
        c = np.sum((1.0 - p) ** N) / self.T
        return float((1.0 - c) * self.T)


def from_schedule(schedule: Schedule) -> TransitionDist:
    """The exact law of Theorem 3.6: P(tau=t) = alpha_{t-1} - alpha_t."""
    return TransitionDist(name=f"thm3.6[{schedule.name}]", T=schedule.T,
                          probs=schedule.transition_probs())


def beta_approx(T: int, a: float, b: float) -> TransitionDist:
    """Beta(a, b) reshaped onto {1..T} (paper §3.2: sample u ~ Beta, t =
    round(u T)).  Used with validation-tuned (a, b), e.g. Beta(15, 7)."""
    # Discretize for expected_nfe / analysis; sampling can use either path.
    edges = np.linspace(0.0, 1.0, T + 1)
    cdf = _beta_cdf(edges, a, b)
    probs = np.diff(cdf)
    probs = np.maximum(probs, 0)
    probs = probs / probs.sum()
    return TransitionDist(name=f"beta({a},{b})", T=T, probs=probs,
                          beta_params=(a, b))


def beta_continuous(a: float, b: float) -> TransitionDist:
    """Continuous Beta(a, b) law for DNDM-C timestamps."""
    return TransitionDist(name=f"beta_c({a},{b})", T=0, probs=None,
                          beta_params=(a, b))


def _beta_cdf(x: np.ndarray, a: float, b: float, n: int = 4096) -> np.ndarray:
    """Regularized incomplete beta via trapezoid quadrature (no scipy)."""
    grid = np.linspace(0.0, 1.0, n + 1)
    # pdf ~ u^(a-1) (1-u)^(b-1); handle endpoint singularities for a,b < 1
    with np.errstate(divide="ignore", invalid="ignore"):
        pdf = grid ** (a - 1.0) * (1.0 - grid) ** (b - 1.0)
    pdf = np.nan_to_num(pdf, posinf=0.0)
    cdf = np.concatenate([[0.0], np.cumsum((pdf[1:] + pdf[:-1]) * 0.5)])
    cdf /= cdf[-1]
    return np.interp(x, grid, cdf)


# ------------------------------------------------------------------
# Transition sets
# ------------------------------------------------------------------

def sample_transition_times(
    key: jax.Array,
    dist: TransitionDist,
    batch: int,
    N: int,
    order: Literal["iid", "l2r", "r2l"] = "iid",
    shared: bool = False,
    continuous: bool = False,
) -> Array:
    """Sample tau for every token: (batch, N) int32 in {1..T}, or f32 in
    (0, 1] with ``continuous=True`` (DNDM-C timestamps).

    ``order`` implements App. C Table 6: "l2r" reassigns the sampled times so
    that left positions transition *later in forward time* — i.e. they are
    denoised (revealed) earlier in the reverse process, which the paper found
    to work best; "r2l" is the mirror image.

    ``shared=True`` draws ONE transition-time set and broadcasts it across
    the batch — this matches the paper's batched NFE accounting (Tables
    7/8 report per-batch NFE ~= per-row E|T|), since the network is called
    once per unique time in the whole batch.
    """
    draw = dist.sample_continuous if continuous else dist.sample
    if shared:
        tau1 = draw(key, (1, N))
        if not continuous:
            tau1 = tau1.astype(jnp.int32)
        tau = jnp.broadcast_to(tau1, (batch, N))
    else:
        tau = draw(key, (batch, N))
        if not continuous:
            tau = tau.astype(jnp.int32)
    if order == "iid":
        return tau
    # sort each row's times; assign descending (l2r) or ascending (r2l)
    srt = jnp.sort(tau, axis=-1)
    if order == "l2r":
        return srt[:, ::-1]  # leftmost token gets the largest tau
    return srt


def nfe_of(tau: Array, T: int) -> Array:
    """|T| per batch row: number of *distinct* transition times (the NFE)."""
    # bincount over {1..T} per row
    def row(tr):
        counts = jnp.zeros((T + 1,), jnp.int32).at[tr].add(1)
        return (counts[1:] > 0).sum()
    return jax.vmap(row)(tau)


def transition_mask_per_step(tau: Array, T: int) -> Array:
    """(T, batch) bool: does step t host at least one transition in the row?"""
    def row(tr):
        counts = jnp.zeros((T + 1,), jnp.int32).at[tr].add(1)
        return counts[1:] > 0
    return jnp.moveaxis(jax.vmap(row)(tau), -1, 0)


def expected_nfe_mc(dist: TransitionDist, N: int, batch: int,
                    key: jax.Array) -> float:
    """Monte-Carlo E|T| (used in tests against Theorem D.1)."""
    tau = dist.sample(key, (batch, N))
    return float(jnp.mean(nfe_of(tau, dist.T)))
