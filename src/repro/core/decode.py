"""Decode-update backend layer.

Every sampler's hot path decodes x0_hat from the (B, N, K) denoiser
logits and folds it into the running token buffer.  This module is the
single place where that happens — ``fused_update`` (select x0 + eq. (9))
and ``decode_tokens`` ((token, score) pairs for the confidence-ranked
samplers) — behind three interchangeable backends:

  * ``"pallas"``    — the streaming kernels in ``kernels/dndm_update``
                      and ``kernels/decode_scores`` compiled to Mosaic;
                      never materialize the log-softmax / argmax
                      intermediate in HBM.
  * ``"interpret"`` — the same kernel under the Pallas interpreter
                      (CPU/GPU debugging; slow, bit-identical tokens).
  * ``"reference"`` — pure jnp (fast on CPU, the correctness oracle).

``backend="auto"`` (the default everywhere) resolves to ``"pallas"`` on
TPU and ``"reference"`` elsewhere; set ``REPRO_DECODE_BACKEND`` to force
a specific backend process-wide.

Decode modes follow ``SamplerConfig.x0_mode``: ``"argmax"`` picks the
highest adjusted logit; ``"sample"`` draws categorically via the
Gumbel-max trick (argmax of logits/temp + mask + Gumbel(0,1) noise), so
all three backends produce bitwise-identical tokens under a fixed key.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro import obs
from repro.kernels.decode_scores import ops as _sops
from repro.kernels.decode_scores import ref as _sref
from repro.kernels.dndm_update import ops as _ops
from repro.kernels.dndm_update import ref as _ref

Array = jnp.ndarray

BACKENDS = ("pallas", "interpret", "reference")


def default_backend() -> str:
    env = os.environ.get("REPRO_DECODE_BACKEND", "").strip()
    backend = env or ("pallas" if jax.default_backend() == "tpu"
                      else "reference")
    if backend not in BACKENDS:
        raise ValueError(f"REPRO_DECODE_BACKEND={env!r}; pick one of "
                         f"{BACKENDS}")
    return backend


def resolve_backend(backend: str | None = "auto") -> str:
    if backend in (None, "auto"):
        return default_backend()
    if backend not in BACKENDS:
        raise ValueError(f"unknown decode backend {backend!r}; pick one of "
                         f"{BACKENDS} or 'auto'")
    return backend


def _gumbel(key: jax.Array, shape, x0_mode: str) -> Array | None:
    if x0_mode == "argmax":
        return None
    if x0_mode != "sample":
        raise ValueError(f"unknown x0_mode {x0_mode!r}")
    return jax.random.gumbel(key, shape, jnp.float32)


def fused_update(key: jax.Array, logits: Array, x: Array, tau: Array, t,
                 noise, cfg, *, version: int = 1, backend: str = "auto",
                 block_n: int = 256, block_v: int = 1024,
                 gumbel: Array | None = None) -> Array:
    """Decode x0_hat and apply the eq. (9) token update in one pass.

    ``x_{t-1} = where(tau == t, x0_hat, x_t)`` (``tau >= t`` for
    Algorithm 3 / version=2).  Returns the updated tokens (B, N) int32.
    All backends agree bitwise on the result for a fixed ``key``.

    ``gumbel`` overrides the internally drawn Gumbel tensor (sample mode
    only) — the stepwise serving path draws one (N, K) slab per row from
    that row's own key stream so that rows at different diffusion times
    reproduce their solo-run noise bit-for-bit; ``key`` may then be None.

    Memory note: argmax mode is the fully streaming path.  Sample mode
    materializes a (B, N, K) f32 Gumbel tensor so that every backend sees
    identical noise (the bitwise-parity contract); replacing it with
    in-kernel per-tile counter-based PRNG would recover the streaming
    property at the cost of backend-portable determinism.
    """
    backend = resolve_backend(backend)
    if obs.enabled():
        # counted at trace time when called from jitted code: one inc per
        # compiled program, i.e. "which backend serves this sampler"
        obs.counter("decode.backend_calls").inc(op="fused_update",
                                                backend=backend)
    mask = noise.logit_mask(jnp.float32)
    if gumbel is None:
        gumbel = _gumbel(key, logits.shape, cfg.x0_mode)
    t = jnp.asarray(t, jnp.int32)
    if backend == "reference":
        out = _ref.dndm_update_ref(logits, x, tau.astype(jnp.int32),
                                   t.reshape(1), version=version, mask=mask,
                                   temperature=cfg.temperature,
                                   gumbel=gumbel)
        return out.astype(jnp.int32)
    return _ops.dndm_update(logits, x, tau, t, mask=mask, gumbel=gumbel,
                            version=version, temperature=cfg.temperature,
                            block_n=block_n, block_v=block_v,
                            interpret=(backend == "interpret"))


def decode_tokens(key: jax.Array, logits: Array, noise, cfg, *,
                  backend: str = "auto", block_n: int = 256,
                  block_v: int = 1024,
                  gumbel: Array | None = None) -> tuple[Array, Array]:
    """Pick x0_hat from logits; returns (tokens (B,N), scores (B,N)).

    Scores are the per-token log-probabilities of the chosen token —
    exactly the quantity RDM-k / DNDM-k rank on (paper App. E).  Tokens
    come from the same adjusted-logit argmax / Gumbel-max the fused
    kernel computes, so they agree with ``fused_update`` bitwise across
    every backend.  Backend resolution is identical to ``fused_update``
    (``backend="auto"``, ``REPRO_DECODE_BACKEND`` respected); the
    pallas/interpret path is the streaming ``kernels/decode_scores`` op —
    a running (max, argmax, logsumexp) triple in VMEM across vocab tiles,
    never materializing the (B, N, K) log-softmax in HBM.

    ``gumbel`` overrides the internal draw exactly as in
    :func:`fused_update` (per-row noise for the stepwise serving path).
    """
    backend = resolve_backend(backend)
    if obs.enabled():
        obs.counter("decode.backend_calls").inc(op="decode_tokens",
                                                backend=backend)
    mask = noise.logit_mask(jnp.float32)
    if gumbel is None:
        gumbel = _gumbel(key, logits.shape, cfg.x0_mode)
    if backend == "reference":
        return _sref.decode_scores_ref(logits, mask=mask,
                                       temperature=cfg.temperature,
                                       gumbel=gumbel)
    return _sops.decode_scores(logits, mask=mask, gumbel=gumbel,
                               temperature=cfg.temperature, block_n=block_n,
                               block_v=block_v,
                               interpret=(backend == "interpret"))
