"""Training objectives for discrete diffusion denoisers.

The paper (App. B.2/B.3) shows DNDM's ELBO matches the standard discrete
diffusion ELBO up to reweighting, so the network is trained exactly as in
D3PM/RDM and reused *training-free* by every sampler here.

We provide:
  * ``reparam_ce_loss`` — the RDM (Zheng et al. 2023) reparameterized
    cross-entropy: corrupt x0 -> x_t, predict x0, CE on corrupted positions
    with optional lambda_t reweighting.  Simple, powerful, the paper's
    training recipe.
  * ``elbo_loss`` — the Hoogeboom-style variational bound with the
    categorical-posterior KL (eq. 5 / eq. 15), for completeness and tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import forward
from repro.core.noise import NoiseDist
from repro.core.posterior import posterior
from repro.core.schedules import Schedule

Array = jnp.ndarray


def _ce(logits: Array, targets: Array) -> Array:
    """Per-token cross entropy, stable."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return logz - gold


def reparam_ce_loss(key: jax.Array, apply_fn, params, x0: Array,
                    schedule: Schedule, noise: NoiseDist,
                    cond: dict | None = None,
                    continuous_time: bool = False,
                    lambda_weighting: bool = True) -> tuple[Array, dict]:
    """RDM-style loss.  ``apply_fn(params, x_t, t_norm, cond) -> logits``.

    Only corrupted positions contribute (for absorbing this is the masked
    set; for multinomial we condition on the corruption indicator, which the
    trainer knows).  Returns (scalar loss, metrics).
    """
    if continuous_time:
        x_t, t, alpha_t = forward.corrupt_continuous(key, x0, schedule, noise)
        t_norm = t
    else:
        x_t, t, alpha_t = forward.corrupt_for_training(key, x0, schedule, noise)
        t_norm = t.astype(jnp.float32) / schedule.T
    logits = apply_fn(params, x_t, t_norm, cond)
    ce = _ce(logits, x0)                      # (B, N)
    corrupted = (x_t != x0) if noise.kind == "multinomial" else (
        x_t == noise.mask_id)
    # Multinomial corruption can coincide with x0 by chance; also train
    # lightly on apparently-clean positions so p(x0|x_t) is calibrated.
    w = jnp.where(corrupted, 1.0, 0.05)
    if lambda_weighting:
        # lambda_t = 1 - alpha_t emphasises noisier examples (RDM App. E)
        w = w * (1.0 - alpha_t)[:, None]
    loss = (ce * w).sum() / jnp.maximum(w.sum(), 1e-6)
    acc = ((logits.argmax(-1) == x0) & corrupted).sum() / jnp.maximum(
        corrupted.sum(), 1)
    return loss, {"loss": loss, "masked_acc": acc,
                  "frac_corrupted": corrupted.mean()}


def elbo_loss(key: jax.Array, apply_fn, params, x0: Array,
              schedule: Schedule, noise: NoiseDist,
              cond: dict | None = None) -> tuple[Array, dict]:
    """Single-t Monte-Carlo estimate of the negative ELBO (eq. 5).

    L_t = KL(q(x_{t-1}|x_t,x0) || p_theta(x_{t-1}|x_t)) with the
    theta_post parameterization; L_1 = -log p_theta(x0|x1).
    """
    k_c, k_t = jax.random.split(key)
    B = x0.shape[0]
    t = jax.random.randint(k_t, (B,), 1, schedule.T + 1)
    x_t, t, alpha_t = forward.corrupt_for_training(
        k_c, x0, schedule, noise, t=t)
    alphas = jnp.asarray(schedule.alphas, dtype=jnp.float32)
    alpha_tm1 = alphas[t - 1]
    t_norm = t.astype(jnp.float32) / schedule.T
    logits = apply_fn(params, x_t, t_norm, cond)
    x0_probs = jax.nn.softmax(logits, axis=-1)

    a_tm1 = alpha_tm1[:, None]
    a_t = alpha_t[:, None]
    q_post = posterior(x_t, jax.nn.one_hot(x0, noise.vocab_size), a_tm1, a_t,
                       noise)
    p_post = posterior(x_t, x0_probs, a_tm1, a_t, noise)
    kl = (q_post * (jnp.log(q_post + 1e-20) - jnp.log(p_post + 1e-20))).sum(-1)
    l1 = _ce(logits, x0)                      # reconstruction at t == 1
    per_tok = jnp.where((t == 1)[:, None], l1, kl)
    # Each term is an unbiased single-sample estimate of its summand; the
    # uniform t draw gives the ELBO up to the constant factor T.
    loss = per_tok.mean() * schedule.T
    return loss, {"elbo_loss": loss, "kl_mean": kl.mean()}
