"""Reverse-process posteriors q(x_{t-1} | x_t, x0) for D3PM baselines.

Multinomial (uniform noise), Hoogeboom et al. 2021b eq. (15) form:
    theta_post(x_t, x0) ∝ (beta_t x_t + (1-beta_t)/K 1)
                        ⊙ (alpha_{t-1} x0 + (1-alpha_{t-1})/K 1)
with the network's predicted distribution substituted for the one-hot x0.

Absorbing (Austin et al. 2021, see paper App. B.1):
    if x_t = [MASK]: x_{t-1} = [MASK] w.p. (1-alpha_{t-1})/(1-alpha_t)
                     x_{t-1} = x0     w.p. (alpha_{t-1}-alpha_t)/(1-alpha_t)
    else:            x_{t-1} = x_t.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.noise import NoiseDist

Array = jnp.ndarray


def multinomial_posterior(x_t: Array, x0_probs: Array, alpha_tm1: Array,
                          alpha_t: Array, vocab_size: int) -> Array:
    """theta_post over x_{t-1}.  x_t: (..., N) ids; x0_probs: (..., N, K).

    alpha_* broadcast against x_t (scalars or (...,1) shaped).
    Returns (..., N, K) normalized probabilities.
    """
    K = vocab_size
    beta_t = alpha_t / jnp.maximum(alpha_tm1, 1e-12)
    xt_onehot = jax.nn.one_hot(x_t, K, dtype=x0_probs.dtype)
    a = beta_t[..., None] * xt_onehot + (1.0 - beta_t)[..., None] / K
    b = alpha_tm1[..., None] * x0_probs + (1.0 - alpha_tm1)[..., None] / K
    p = a * b
    return p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)


def absorbing_posterior(x_t: Array, x0_probs: Array, alpha_tm1: Array,
                        alpha_t: Array, noise: NoiseDist) -> Array:
    """Posterior over x_{t-1} for absorbing diffusion.  Shapes as above."""
    K = noise.vocab_size
    mask_id = noise.mask_id
    denom = jnp.maximum(1.0 - alpha_t, 1e-12)
    p_stay = ((1.0 - alpha_tm1) / denom)[..., None]     # stay masked
    p_reveal = ((alpha_tm1 - alpha_t) / denom)[..., None]  # reveal as x0
    mask_onehot = jax.nn.one_hot(
        jnp.full(x_t.shape, mask_id), K, dtype=x0_probs.dtype)
    # forbid the network from revealing [MASK] itself
    x0p = x0_probs * (1.0 - mask_onehot)
    x0p = x0p / jnp.maximum(x0p.sum(-1, keepdims=True), 1e-30)
    masked_branch = p_stay * mask_onehot + p_reveal * x0p
    clean_branch = jax.nn.one_hot(x_t, K, dtype=x0_probs.dtype)
    is_masked = (x_t == mask_id)[..., None]
    return jnp.where(is_masked, masked_branch, clean_branch)


def posterior(x_t: Array, x0_probs: Array, alpha_tm1: Array, alpha_t: Array,
              noise: NoiseDist) -> Array:
    if noise.kind == "multinomial":
        return multinomial_posterior(x_t, x0_probs, alpha_tm1, alpha_t,
                                     noise.vocab_size)
    return absorbing_posterior(x_t, x0_probs, alpha_tm1, alpha_t, noise)
