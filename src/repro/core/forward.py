"""Forward (corruption) processes.

Two mathematically distinct trajectory laws with identical marginals
(paper Thm 3.1):

  * ``markov_trajectory``     — eq. (1):  x_t = b_t x_{t-1} + (1-b_t) w_t
  * ``non_markov_trajectory`` — eq. (6):  x_t = b_t x_{t-1} + (1-b_t) w
                                (one shared noise draw per token)

plus the closed-form marginal sampler ``sample_xt`` used for training
(eq. 3): x_t = x_0 w.p. alpha_t else ~ q_noise.

All functions operate on integer token ids of shape (..., N).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.noise import NoiseDist
from repro.core.schedules import Schedule

Array = jnp.ndarray


def sample_xt(key: jax.Array, x0: Array, alpha_t: Array,
              noise: NoiseDist) -> Array:
    """Sample x_t ~ q(x_t | x_0) = Cat(alpha_t x_0 + (1-alpha_t) q_noise).

    ``alpha_t`` broadcasts against ``x0`` (scalar, per-batch, or per-token).
    """
    k_keep, k_noise = jax.random.split(key)
    keep = jax.random.bernoulli(k_keep, jnp.broadcast_to(alpha_t, x0.shape))
    w = noise.sample(k_noise, x0.shape)
    return jnp.where(keep, x0, w)


def non_markov_trajectory(key: jax.Array, x0: Array, schedule: Schedule,
                          noise: NoiseDist) -> Array:
    """Full DNDM trajectory {x_t}_{t=0..T} via eq. (6).

    Implemented through the transition-time characterization (eq. 7):
    sample tau per token, then x_t = x0 if t < tau else w, with a single
    shared w per token.  Returns (T+1, ...) stacked trajectory.
    """
    k_tau, k_w = jax.random.split(key)
    probs = jnp.asarray(schedule.transition_probs())
    # tau in {1..T}
    tau = 1 + jax.random.categorical(
        k_tau, jnp.log(probs + 1e-30), shape=x0.shape)
    w = noise.sample(k_w, x0.shape)
    ts = jnp.arange(schedule.T + 1).reshape((-1,) + (1,) * x0.ndim)
    return jnp.where(ts < tau[None], x0[None], w[None])


def markov_trajectory(key: jax.Array, x0: Array, schedule: Schedule,
                      noise: NoiseDist) -> Array:
    """Full D3PM trajectory {x_t}_{t=0..T} via eq. (1) (fresh w_t each step)."""
    betas = jnp.asarray(schedule.betas)

    def step(x_prev, inp):
        beta_t, k = inp
        kb, kw = jax.random.split(k)
        b = jax.random.bernoulli(kb, jnp.broadcast_to(beta_t, x_prev.shape))
        w = noise.sample(kw, x_prev.shape)
        x_t = jnp.where(b, x_prev, w)
        return x_t, x_t

    keys = jax.random.split(key, schedule.T)
    _, traj = jax.lax.scan(step, x0, (betas, keys))
    return jnp.concatenate([x0[None], traj], axis=0)


def corrupt_for_training(key: jax.Array, x0: Array, schedule: Schedule,
                         noise: NoiseDist,
                         t: Array | None = None) -> tuple[Array, Array, Array]:
    """Training-time corruption: sample t ~ Unif{1..T} (or use given t),
    then x_t ~ q(x_t|x_0).  Returns (x_t, t, alpha_t).

    ``t`` has shape x0.shape[:1] (one timestep per example, as in RDM).
    """
    k_t, k_x = jax.random.split(key)
    B = x0.shape[0]
    if t is None:
        t = jax.random.randint(k_t, (B,), 1, schedule.T + 1)
    alphas = jnp.asarray(schedule.alphas, dtype=jnp.float32)
    alpha_t = alphas[t]
    bshape = (B,) + (1,) * (x0.ndim - 1)
    x_t = sample_xt(k_x, x0, alpha_t.reshape(bshape), noise)
    return x_t, t, alpha_t


def corrupt_continuous(key: jax.Array, x0: Array, schedule: Schedule,
                       noise: NoiseDist) -> tuple[Array, Array, Array]:
    """Continuous-time corruption for DNDM-C style training (§3.3, App G.1):
    t ~ Unif[0, 1], x_t = x0 w.p. alpha(t).  Returns (x_t, t, alpha_t)."""
    k_t, k_x = jax.random.split(key)
    B = x0.shape[0]
    t = jax.random.uniform(k_t, (B,))
    alpha_t = schedule.alpha_fn(t)
    bshape = (B,) + (1,) * (x0.ndim - 1)
    x_t = sample_xt(k_x, x0, alpha_t.reshape(bshape), noise)
    return x_t, t, alpha_t
