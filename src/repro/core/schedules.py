"""Noise schedules for discrete diffusion.

A schedule is the sequence ``alpha_t = prod_{s<=t} beta_s`` decreasing from
``alpha_0 = 1`` to ``alpha_T ~= 0`` (paper §2, eq. 3).  We expose both the
discrete arrays used by finite-step samplers and the continuous function
``alpha(t), t in [0, 1]`` used by DNDM-C (paper §3.3; a schedule is
*scale-invariant* when ``alpha_{ct}(cT) = alpha_t(T)``, in which case the
continuous limit is well defined).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Discrete alpha schedule with its continuous counterpart.

    Attributes:
      name: schedule identifier.
      T: number of diffusion steps.
      alphas: array of shape (T + 1,), ``alphas[0] == 1``, decreasing,
        ``alphas[T]`` close to 0.  ``alphas[t] = P(token still clean at t)``.
      alpha_fn: continuous ``alpha(t)`` on [0, 1] (the scale-invariant limit).
    """

    name: str
    T: int
    alphas: np.ndarray
    alpha_fn: Callable[[Array], Array]

    @property
    def betas(self) -> np.ndarray:
        """Per-step survival probabilities ``beta_t = alpha_t / alpha_{t-1}``."""
        a = self.alphas
        return a[1:] / np.maximum(a[:-1], 1e-12)

    def transition_probs(self) -> np.ndarray:
        """``P(tau = t) = alpha_{t-1} - alpha_t`` for t = 1..T (Theorem 3.6)."""
        p = self.alphas[:-1] - self.alphas[1:]
        # Guard tiny negative rounding and renormalize to a proper law.
        p = np.maximum(p, 0.0)
        s = p.sum()
        if s <= 0:
            raise ValueError(f"degenerate schedule {self.name!r}")
        return p / s


def _as_alphas(name: str, T: int, g: Callable[[np.ndarray], np.ndarray],
               alpha_fn: Callable[[Array], Array]) -> Schedule:
    t = np.arange(T + 1, dtype=np.float64) / T
    a = np.clip(g(t), 0.0, 1.0)
    a[0] = 1.0
    a[T] = 0.0
    # enforce monotone decrease
    a = np.minimum.accumulate(a)
    return Schedule(name=name, T=T, alphas=a, alpha_fn=alpha_fn)


def linear(T: int) -> Schedule:
    """``alpha_t = 1 - t/T`` (Austin et al. 2021) => uniform transition law."""
    return _as_alphas("linear", T, lambda t: 1.0 - t, lambda t: 1.0 - t)


def cosine(T: int, s: float = 0.008) -> Schedule:
    """``alpha_t = cos(pi/2 * (t/T + s)/(1+s)) / cos(pi/2 * s/(1+s))``."""
    c0 = math.cos(0.5 * math.pi * s / (1 + s))

    def g(t):
        return np.cos(0.5 * np.pi * (t + s) / (1 + s)) / c0

    def alpha_fn(t):
        return jnp.cos(0.5 * jnp.pi * (t + s) / (1 + s)) / c0

    return _as_alphas("cosine", T, g, alpha_fn)


def cosine_sq(T: int, s: float = 0.008) -> Schedule:
    """``alpha_t = cos^2(...)`` (Zheng et al. 2023 / Nichol & Dhariwal)."""
    c0 = math.cos(0.5 * math.pi * s / (1 + s)) ** 2

    def g(t):
        return np.cos(0.5 * np.pi * (t + s) / (1 + s)) ** 2 / c0

    def alpha_fn(t):
        return jnp.cos(0.5 * jnp.pi * (t + s) / (1 + s)) ** 2 / c0

    return _as_alphas("cosine_sq", T, g, alpha_fn)


def from_alpha_fn(name: str, T: int, alpha_fn: Callable[[Array], Array]) -> Schedule:
    """Discretize an arbitrary continuous ``alpha(t)`` onto T steps."""
    t = np.arange(T + 1, dtype=np.float64) / T
    a = np.asarray(alpha_fn(jnp.asarray(t)), dtype=np.float64)
    a = np.clip(a, 0.0, 1.0)
    a[0], a[T] = 1.0, 0.0
    a = np.minimum.accumulate(a)
    return Schedule(name=name, T=T, alphas=a, alpha_fn=alpha_fn)


_REGISTRY: dict[str, Callable[[int], Schedule]] = {
    "linear": linear,
    "cosine": cosine,
    "cosine_sq": cosine_sq,
}


def get(name: str, T: int) -> Schedule:
    if name not in _REGISTRY:
        raise KeyError(f"unknown schedule {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](T)
