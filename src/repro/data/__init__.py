"""Data substrate: tokenizers, synthetic corpora, batch pipeline."""
from repro.data.pipeline import DataConfig, DataPipeline
from repro.data.synthetic import MarkovLanguage, TranslationTask, bleu
from repro.data.tokenizer import ByteTokenizer, CharTokenizer

__all__ = ["DataConfig", "DataPipeline", "MarkovLanguage",
           "TranslationTask", "bleu", "ByteTokenizer", "CharTokenizer"]
