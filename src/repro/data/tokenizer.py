"""Tokenizers: character-level (text8-style, 27 symbols) and byte-level
(enwik8-style, 256 symbols), plus special ids.

The absorbing [MASK] token is appended *after* the base vocabulary, so
``vocab_size = base + 1`` for absorbing-diffusion models and ``base`` for
multinomial ones.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class CharTokenizer:
    """Lower-case letters + space (text8's 27 categories)."""

    alphabet: str = "abcdefghijklmnopqrstuvwxyz "

    @property
    def base_size(self) -> int:
        return len(self.alphabet)

    def encode(self, text: str) -> np.ndarray:
        lut = {c: i for i, c in enumerate(self.alphabet)}
        return np.asarray([lut.get(c, self.base_size - 1) for c in text],
                          np.int32)

    def decode(self, ids) -> str:
        return "".join(self.alphabet[int(i)] if 0 <= int(i) <
                       self.base_size else "?" for i in np.asarray(ids))


@dataclasses.dataclass(frozen=True)
class ByteTokenizer:
    """Raw bytes (enwik8's 256 categories)."""

    @property
    def base_size(self) -> int:
        return 256

    def encode(self, text: str) -> np.ndarray:
        return np.frombuffer(text.encode("utf-8", "replace"),
                             np.uint8).astype(np.int32)

    def decode(self, ids) -> str:
        return bytes(int(i) & 0xFF for i in np.asarray(ids)).decode(
            "utf-8", "replace")
