"""Deterministic synthetic corpora (offline container: no downloads).

Two task families mirroring the paper's experiments:

* ``markov_language`` — unconditional generation (text8/enwik8 analog):
  a seeded order-2 Markov chain over the character alphabet whose
  transition table is itself sampled once from a Dirichlet, giving text
  with strong local statistics a model can learn and a held-out
  perplexity that is meaningful to compare across samplers.

* ``translation_pairs`` — conditional seq2seq (IWSLT/WMT analog): the
  "source" is Markov-language text; the "target" is a deterministic
  cipher + per-word reversal of the source.  Exact references exist, so
  BLEU against them behaves like the paper's Tables 2/3 quality axis.
"""
from __future__ import annotations

import numpy as np


class MarkovLanguage:
    """Order-1 character Markov chain with a seeded, SPARSE transition
    table (each state can reach only ``branching`` successors).

    Sparsity makes the language *learnable* rather than a pure
    |V|^order lookup-memorization task: a small denoiser reaches well
    below the entropy of uniform noise within a few hundred steps, which
    is what the quality benchmarks need on CPU.
    """

    def __init__(self, vocab: int, seed: int = 0, branching: int = 4):
        rng = np.random.default_rng(seed)
        self.vocab = vocab
        branching = min(branching, vocab)
        table = np.zeros((vocab, vocab), np.float64)
        for a in range(vocab):
            succ = rng.choice(vocab, size=branching, replace=False)
            w = rng.dirichlet(np.full(branching, 0.7))
            table[a, succ] = w
        self.table = table / table.sum(-1, keepdims=True)

    def sample(self, rng: np.random.Generator, length: int) -> np.ndarray:
        return self.sample_batch(rng, 1, length)[0]

    def sample_batch(self, rng: np.random.Generator, batch: int,
                     length: int) -> np.ndarray:
        """Vectorized batch sampling via inverse-CDF on shared uniforms."""
        cdf = np.cumsum(self.table, axis=-1)
        a = rng.integers(self.vocab, size=batch)
        u = rng.random((length, batch))
        out = np.empty((batch, length), np.int32)
        for i in range(length):
            c = (cdf[a] < u[i][:, None]).sum(-1)
            out[:, i] = c
            a = c
        return out

    def log_likelihood(self, seq: np.ndarray) -> float:
        """Per-token log-likelihood under the true chain (quality oracle).

        Out-of-alphabet ids (e.g. a stray [MASK]) score as impossible
        transitions (p = 1e-12) rather than crashing.
        """
        seq = np.asarray(seq)
        if seq.ndim == 1:
            seq = seq[None]
        a = seq[:, :-1].reshape(-1)
        b = seq[:, 1:].reshape(-1)
        ok = (a < self.vocab) & (b < self.vocab) & (a >= 0) & (b >= 0)
        p = np.where(ok, self.table[np.minimum(a, self.vocab - 1),
                                    np.minimum(b, self.vocab - 1)], 0.0)
        return float(np.log(np.maximum(p, 1e-12)).mean())


def cipher_permutation(vocab: int, seed: int = 1) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.permutation(vocab).astype(np.int32)


def translate(src: np.ndarray, perm: np.ndarray, space_id: int,
              reverse_words: bool = False) -> np.ndarray:
    """Deterministic 'translation': cipher each token; optionally also
    reverse each word (harder positional task).  Spaces are word
    boundaries and map to themselves.
    """
    src = np.asarray(src)
    if not reverse_words:
        return perm[src]
    out = np.empty_like(src)
    if src.ndim == 2:
        for i, row in enumerate(src):
            out[i] = translate(row, perm, space_id, True)
        return out
    start = 0
    for i in range(len(src) + 1):
        if i == len(src) or src[i] == space_id:
            out[start:i] = perm[src[start:i]][::-1]
            if i < len(src):
                out[i] = space_id
            start = i + 1
    return out


class TranslationTask:
    """Paired (source, target) sentences with exact references."""

    def __init__(self, vocab: int, space_id: int | None = None,
                 seed: int = 0, reverse_words: bool = False):
        self.vocab = vocab
        self.space_id = vocab - 1 if space_id is None else space_id
        self.reverse_words = reverse_words
        self.lang = MarkovLanguage(vocab, seed=seed)
        # bijective cipher that pins the space (word boundaries preserved)
        self.perm = _fix_perm(cipher_permutation(vocab, seed=seed + 1),
                              self.space_id, vocab)

    def sample_pairs(self, rng: np.random.Generator, batch: int,
                     length: int) -> tuple[np.ndarray, np.ndarray]:
        src = self.lang.sample_batch(rng, batch, length)
        tgt = translate(src, self.perm, self.space_id, self.reverse_words)
        return src, tgt


def _fix_perm(perm: np.ndarray, pin: int, vocab: int) -> np.ndarray:
    """Repair a permutation so that perm[pin] == pin and it stays bijective."""
    perm = perm.copy()
    cur = int(np.where(perm == pin)[0][0])
    perm[cur], perm[pin] = perm[pin], pin
    assert len(set(perm.tolist())) == vocab
    return perm


def bleu(hyp: np.ndarray, ref: np.ndarray, max_n: int = 4) -> float:
    """Corpus BLEU on token ids (uniform n-gram weights, brevity penalty).

    hyp/ref: (B, N) arrays (equal length here, BP == 1, but kept general).
    """
    hyp = np.asarray(hyp)
    ref = np.asarray(ref)
    if hyp.ndim == 1:
        hyp, ref = hyp[None], ref[None]
    logs = []
    for n in range(1, max_n + 1):
        match, total = 0, 0
        for h, r in zip(hyp, ref):
            h_ngrams: dict = {}
            r_ngrams: dict = {}
            for i in range(len(h) - n + 1):
                g = tuple(h[i:i + n])
                h_ngrams[g] = h_ngrams.get(g, 0) + 1
            for i in range(len(r) - n + 1):
                g = tuple(r[i:i + n])
                r_ngrams[g] = r_ngrams.get(g, 0) + 1
            for g, c in h_ngrams.items():
                match += min(c, r_ngrams.get(g, 0))
            total += max(len(h) - n + 1, 0)
        logs.append(np.log(max(match, 1e-9) / max(total, 1)))
    hyp_len = sum(len(h) for h in hyp)
    ref_len = sum(len(r) for r in ref)
    bp = min(1.0, np.exp(1 - ref_len / max(hyp_len, 1)))
    return float(100.0 * bp * np.exp(np.mean(logs)))
