"""Input pipeline: deterministic, seeded, shardable batch streams.

``DataPipeline`` yields numpy batches; the trainer moves them onto the
mesh with the declared batch sharding (data axis).  Unconditional batches
are {"x0": (B, N)}; conditional ones add {"src": (B, P)} — the source
prefix that stays clean during diffusion.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.data.synthetic import MarkovLanguage, TranslationTask


@dataclasses.dataclass
class DataConfig:
    task: str = "unconditional"      # unconditional | translation
    vocab: int = 27                  # base vocab (without [MASK])
    seq_len: int = 64
    src_len: int = 64                # translation source length
    batch: int = 32
    seed: int = 0
    mt_reverse: bool = False         # harder MT: also reverse each word


class DataPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.task == "translation":
            self.task = TranslationTask(cfg.vocab, seed=cfg.seed,
                                        reverse_words=cfg.mt_reverse)
        else:
            self.lang = MarkovLanguage(cfg.vocab, seed=cfg.seed)

    def __iter__(self) -> Iterator[dict]:
        rng = np.random.default_rng(self.cfg.seed + 1)
        while True:
            yield self.batch(rng)

    def batch(self, rng: np.random.Generator) -> dict:
        c = self.cfg
        if c.task == "translation":
            src, tgt = self.task.sample_pairs(rng, c.batch, c.seq_len)
            return {"x0": tgt, "src": src}
        return {"x0": self.lang.sample_batch(rng, c.batch, c.seq_len)}

    def eval_batches(self, n: int, seed: int = 12345) -> list[dict]:
        """Fixed held-out batches (deterministic across runs)."""
        rng = np.random.default_rng(seed)
        return [self.batch(rng) for _ in range(n)]
