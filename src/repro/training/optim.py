"""Optimizers and LR schedules in pure JAX (no optax dependency).

AdamW with decoupled weight decay + global-norm clipping, and the usual
warmup-cosine / warmup-linear schedules.  State is a plain pytree so it
shards with the same rules as the parameters (optimizer sharding ==
parameter sharding, ZeRO-1 style along whatever axes the params use).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

Array = jnp.ndarray
Schedule = Callable[[Array], Array]


def warmup_cosine(peak: float, warmup: int, total: int,
                  floor: float = 0.1) -> Schedule:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0, 1)
        cos = peak * (floor + (1 - floor) * 0.5 *
                      (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return fn


def constant(lr: float) -> Schedule:
    return lambda step: jnp.full((), lr, jnp.float32)


@dataclasses.dataclass(frozen=True)
class AdamW:
    schedule: Schedule
    b1: float = 0.9
    b2: float = 0.98
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0

    def init(self, params) -> dict:
        zeros = jax.tree.map(jnp.zeros_like, params)
        return {"mu": zeros,
                "nu": jax.tree.map(jnp.zeros_like, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params) -> tuple[dict, dict, dict]:
        """Returns (new_params, new_state, metrics)."""
        step = state["step"] + 1
        lr = self.schedule(step)

        if self.clip_norm > 0:
            gnorm = jnp.sqrt(sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, self.clip_norm /
                                jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        else:
            gnorm = jnp.zeros(())

        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                          state["mu"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                          state["nu"], grads)
        mu_hat_scale = 1.0 / (1 - b1 ** step.astype(jnp.float32))
        nu_hat_scale = 1.0 / (1 - b2 ** step.astype(jnp.float32))

        def upd(p, m, v):
            u = (m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + self.eps)
            if p.ndim >= 2:                       # decay matrices only
                u = u + self.weight_decay * p
            return (p - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, {"mu": mu, "nu": nu, "step": step,
                            }, {"lr": lr, "grad_norm": gnorm}

    # convenience: (grads, state, params) -> (params, state, metrics)
    def __call__(self, grads, state, params):
        out = self.update(grads, state, params)
        return out
