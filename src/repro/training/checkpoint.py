"""Checkpointing: flat-path .npz arrays + a JSON manifest (no pickle).

Works for any dict/list/tuple pytree of jax/numpy arrays and python
scalars.  Restores onto host numpy; the caller re-shards with device_put.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

_SEP = "/"


def _flatten(tree, prefix="") -> dict:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{_SEP}{k}" if prefix
                                else str(k)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{_SEP}[{i}]" if prefix
                                else f"[{i}]"))
    else:
        out[prefix] = np.asarray(jax.device_get(tree))
    return out


_NATIVE = {"float64", "float32", "float16", "int64", "int32", "int16",
           "int8", "uint64", "uint32", "uint16", "uint8", "bool"}


def save(path: str, tree) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    manifest = {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                for k, v in flat.items()}
    # numpy can't serialize ml_dtypes (bfloat16, fp8): widen to f32 on
    # disk and restore the dtype from the manifest at load time.
    flat = {k: (v.astype(np.float32) if str(v.dtype) not in _NATIVE else v)
            for k, v in flat.items()}
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    with open((path[:-4] if path.endswith(".npz") else path) +
              ".json", "w") as f:
        json.dump(manifest, f, indent=1)


def load(path: str) -> dict:
    base = path[:-4] if path.endswith(".npz") else path
    npz = np.load(base + ".npz", allow_pickle=False)
    with open(base + ".json") as f:
        manifest = json.load(f)
    tree: dict = {}
    for key in npz.files:
        parts = key.split(_SEP)
        node = tree
        for p in parts[:-1]:
            idx = int(p[1:-1]) if p.startswith("[") else p
            node = node.setdefault(idx, {})
        last = parts[-1]
        idx = int(last[1:-1]) if last.startswith("[") else last
        arr = npz[key]
        want = manifest.get(key, {}).get("dtype")
        if want and want != str(arr.dtype):
            import ml_dtypes
            arr = arr.astype(np.dtype(getattr(ml_dtypes, want, want)))
        node[idx] = arr
    return _lists(tree)


def _lists(node):
    """Convert {0:..,1:..} int-keyed dicts back into lists."""
    if isinstance(node, dict):
        node = {k: _lists(v) for k, v in node.items()}
        if node and all(isinstance(k, int) for k in node):
            return [node[i] for i in range(len(node))]
    return node
