"""Training loop: diffusion-denoiser objective + AdamW, jit/pjit-ready.

``make_train_step`` builds the canonical train step used everywhere:
unit tests (1 device), the example drivers, and the multi-pod dry-run
(jitted with NamedShardings over the production mesh).  Conditional
batches carry a clean source prefix; only target positions are corrupted
and scored (the paper's MT setup with a decoder-only early-fusion twist).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import forward
from repro.core.losses import _ce
from repro.core.noise import NoiseDist
from repro.core.schedules import Schedule
from repro.models.model import Model
from repro.training import checkpoint as ckpt_lib
from repro.training.optim import AdamW

Array = jnp.ndarray


def make_train_step(model: Model, schedule: Schedule, noise: NoiseDist,
                    optimizer: AdamW, *, continuous_time: bool = False,
                    lambda_weighting: bool = True,
                    microbatches: int = 1) -> Callable:
    """Returns step(state, batch, key) -> (state, metrics).

    batch: {"x0": (B, N) int32, optional "src": (B, P) int32,
            optional "frontend_embeds": (B, F, d)}.

    ``microbatches > 1`` = gradient accumulation: the batch is split
    along dim 0 and gradients are averaged over an *unrolled* loop (the
    accumulation dependency chain keeps the live activation set to one
    microbatch — the memory-fit lever for the big MoE trains; unrolled
    rather than scanned so dry-run cost analysis stays exact).
    """
    cfg = model.cfg

    def loss_fn(params, batch, key):
        x0 = batch["x0"]
        if continuous_time:
            x_t, t, alpha_t = forward.corrupt_continuous(
                key, x0, schedule, noise)
            t_norm = t
        else:
            x_t, t, alpha_t = forward.corrupt_for_training(
                key, x0, schedule, noise)
            t_norm = t.astype(jnp.float32) / schedule.T

        src = batch.get("src")
        inp = x_t if src is None else jnp.concatenate([src, x_t], axis=1)
        logits, aux = model.forward(
            params, inp, t_norm, batch.get("frontend_embeds"),
            causal=False)
        if src is not None:
            logits = logits[:, src.shape[1]:]

        ce = _ce(logits, x0)
        corrupted = ((x_t != x0) if noise.kind == "multinomial"
                     else (x_t == noise.mask_id))
        w = jnp.where(corrupted, 1.0, 0.05)
        if lambda_weighting:
            w = w * (1.0 - alpha_t)[:, None]
        ce_loss = (ce * w).sum() / jnp.maximum(w.sum(), 1e-6)
        loss = (ce_loss + cfg.load_balance_weight * aux["load_balance"]
                + cfg.router_z_weight * aux["router_z"])
        acc = ((logits.argmax(-1) == x0) & corrupted).sum() / jnp.maximum(
            corrupted.sum(), 1)
        return loss, {"loss": loss, "ce": ce_loss, "masked_acc": acc,
                      "load_balance": aux["load_balance"]}

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(state, batch, key):
        if microbatches > 1:
            B = batch["x0"].shape[0]
            assert B % microbatches == 0, (B, microbatches)
            mb = B // microbatches
            grads = None
            metrics = None
            for i in range(microbatches):
                sub = {k: v[i * mb:(i + 1) * mb] for k, v in batch.items()}
                (_, m_i), g_i = grad_fn(state["params"], sub,
                                        jax.random.fold_in(key, i))
                if grads is None:
                    grads, metrics = g_i, m_i
                else:
                    grads = jax.tree.map(jnp.add, grads, g_i)
                    metrics = jax.tree.map(jnp.add, metrics, m_i)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda m: m / microbatches, metrics)
        else:
            (_, metrics), grads = grad_fn(state["params"], batch, key)
        params, opt, opt_metrics = optimizer.update(
            grads, state["opt"], state["params"])
        metrics.update(opt_metrics)
        return {"params": params, "opt": opt,
                "step": state["step"] + 1}, metrics

    return step


def init_state(model: Model, optimizer: AdamW, key) -> dict:
    params = model.init(key)
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


@dataclasses.dataclass
class Trainer:
    """Single-host training driver with metrics + checkpointing."""

    model: Model
    schedule: Schedule
    noise: NoiseDist
    optimizer: AdamW
    continuous_time: bool = False
    log_every: int = 20
    ckpt_path: str | None = None
    ckpt_every: int = 0

    def run(self, data: Iterator[dict], steps: int, seed: int = 0,
            state: dict | None = None, verbose: bool = True) -> tuple[dict, list]:
        step_fn = jax.jit(make_train_step(
            self.model, self.schedule, self.noise, self.optimizer,
            continuous_time=self.continuous_time))
        key = jax.random.PRNGKey(seed)
        if state is None:
            key, k0 = jax.random.split(key)
            state = init_state(self.model, self.optimizer, k0)
        history = []
        t0 = time.time()
        for i, batch in enumerate(data):
            if i >= steps:
                break
            key, k = jax.random.split(key)
            batch = {kk: jnp.asarray(v) for kk, v in batch.items()}
            state, metrics = step_fn(state, batch, k)
            if i % self.log_every == 0 or i == steps - 1:
                m = {kk: float(v) for kk, v in metrics.items()}
                m["step"] = i
                m["wall"] = time.time() - t0
                history.append(m)
                if verbose:
                    print(f"step {i:5d} loss {m['loss']:.4f} "
                          f"acc {m['masked_acc']:.3f} "
                          f"lr {m['lr']:.2e} ({m['wall']:.1f}s)")
            if (self.ckpt_path and self.ckpt_every and
                    i and i % self.ckpt_every == 0):
                ckpt_lib.save(self.ckpt_path, state["params"])
        if self.ckpt_path:
            ckpt_lib.save(self.ckpt_path, state["params"])
        return state, history
