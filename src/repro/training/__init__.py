"""Training substrate: optimizer, trainer, checkpoints."""
from repro.training import checkpoint
from repro.training.optim import AdamW, constant, warmup_cosine
from repro.training.trainer import Trainer, init_state, make_train_step

__all__ = ["checkpoint", "AdamW", "constant", "warmup_cosine", "Trainer",
           "init_state", "make_train_step"]
