"""Paper Table 4: unconditional text generation — vanilla multinomial
sampling vs DNDM; perplexity proxy + wall time.

The proxy: generated text is scored by per-token log-likelihood under
the *true* synthetic Markov chain (exp(-ll) plays GPT-2 perplexity's
role: lower = better).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks import common


def run(quick: bool = True) -> list[str]:
    key = jax.random.PRNGKey(3)
    models = {kind: common.unconditional_model(noise_kind=kind)
              for kind in ("multinomial", "absorbing")}
    rows = []
    B = 8
    T = 100 if quick else 1000
    for m, kind in (("d3pm", "multinomial"), ("dndm", "multinomial"),
                    ("d3pm", "absorbing"), ("dndm", "absorbing"),
                    ("dndm_topk", "absorbing")):
        model, params, pipe = models[kind]
        eng = common.engine(model, params, method=m, steps=T,
                            noise_kind=kind)
        out, wall = eng.generate(key, B, common.SEQ)
        ll = common.quality_ll(pipe, out.tokens)
        ppl = float(np.exp(-ll))
        rows.append(common.row(
            f"uncond/T{T}/{m}/{kind}", 1e6 * wall / max(out.nfe, 1),
            f"ppl_proxy={ppl:.2f} nfe={out.nfe} wall_s={wall:.2f}"))
    return rows
