"""Benchmark harness — one function per paper table.

Prints ``name,us_per_call,derived`` CSV rows.  Set REPRO_BENCH_QUICK=0
for the full (slow) grids; default quick mode finishes on a laptop CPU.
``--json PATH`` switches to the per-method perf-baseline emitter
(wall / compile / NFE / tokens-per-second + telemetry snapshot, see
benchmarks/baseline.py; schema validated by ``repro.obs.schema``).
Set ``REPRO_TRACE=trace.jsonl`` to additionally export the span/event
trace (per-step |R_t|, jit-cache, backend selection) as JSON lines.

  bench_nfe           -> Tables 7/8  (avg NFE vs T, Theorem D.1)
  bench_speed         -> Fig. 1/4    (wall-clock scaling in steps)
  bench_quality       -> Tables 2/3  (BLEU + time, conditional MT)
  bench_unconditional -> Table 4     (unconditional text, ppl proxy)
  bench_schedules     -> Table 5     (transition-time schedule ablation)
  bench_order         -> Table 6     (l2r / r2l transition order)
  bench_beta_grid     -> Tables 9/10 (Beta(a,b) grid)
  bench_continuous    -> Tables 11/12 (continuous train/sample)
  bench_maskpredict   -> Table 13    (Mask-Predict comparison)
  roofline            -> EXPERIMENTS §Roofline (from dry-run artifacts)
"""
from __future__ import annotations

import os
import sys
import time
import traceback

QUICK = os.environ.get("REPRO_BENCH_QUICK", "1") == "1"


def _out_path(argv: list, flag: str) -> str:
    i = argv.index(flag)
    try:
        path = argv[i + 1]
    except IndexError:
        path = ""
    if not path or path.startswith("--"):
        raise SystemExit(f"{flag} needs an output path, e.g. "
                         f"{flag} BENCH{flag[1:].replace('-', '_')}.json "
                         "(quick mode is REPRO_BENCH_QUICK=1, not a flag)")
    return path

MODULES = [
    "bench_nfe", "bench_speed", "bench_quality", "bench_unconditional",
    "bench_schedules", "bench_order", "bench_beta_grid",
    "bench_continuous", "bench_maskpredict", "bench_static_budget",
    "bench_ddim",
    "roofline",
]


def main() -> None:
    argv = sys.argv[1:]
    if "--json" in argv:
        # perf-baseline mode: per-method wall/NFE/tokens-per-second JSON
        # (see benchmarks/baseline.py) instead of the CSV table sweep
        path = _out_path(argv, "--json")
        from benchmarks.baseline import emit
        emit(path, quick=QUICK)
        return
    if "--serving-live" in argv:
        # live-observability leg: the Poisson serving benchmark with the
        # HTTP exporter up, /metrics scraped+validated mid-run, and the
        # live p95 checked against the artifact sketch (obs_live.py);
        # CI gates the artifact with `python -m repro.obs.regress`
        path = _out_path(argv, "--serving-live")
        from benchmarks.obs_live import run_live
        run_live(path, quick=QUICK)
        return
    if "--serving-registry" in argv:
        # full-registry serving leg: every registered method through the
        # drain and continuous schedulers (see benchmarks/serving.py)
        path = _out_path(argv, "--serving-registry")
        from benchmarks.serving import emit_registry
        emit_registry(path, quick=QUICK)
        return
    if "--serving" in argv:
        # Poisson-arrival serving benchmark: drain vs continuous batching
        # (see benchmarks/serving.py; "kind": "serving" schema-2 JSON)
        path = _out_path(argv, "--serving")
        from benchmarks.serving import emit
        emit(path, quick=QUICK)
        return
    only = argv or MODULES
    from benchmarks.common import available_methods
    # stderr: stdout stays a machine-readable CSV stream
    print(f"# engine methods: {', '.join(available_methods())}",
          file=sys.stderr)
    print("name,us_per_call,derived")
    for name in MODULES:
        if name not in only:
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            rows = mod.run(quick=QUICK)
            for r in rows:
                print(r, flush=True)
            print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception as e:  # noqa: BLE001
            print(f"# {name} FAILED: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)


if __name__ == "__main__":
    main()
