"""Live-observability drive: the Poisson serving benchmark with the
HTTP exporter up, scraped mid-run, and the scrape validated.

``python -m benchmarks.run --serving-live BENCH_obs_live.json`` (the CI
``obs-live`` leg) does, in one process:

1. start the :mod:`repro.obs.exporter` HTTP server
   (``REPRO_METRICS_PORT`` or an ephemeral port);
2. run :func:`benchmarks.serving.emit` on a background thread while the
   main thread polls ``/metrics`` until a scrape shows serving traffic
   (a ``scheduler_service_seconds`` quantile sample) — i.e. a *mid-run*
   scrape, with schedulers actively recording, exercising the
   lock-consistent snapshot path;
3. round-trip the scrape through ``exporter.parse_prometheus_text`` and
   save it next to the JSON artifact (``<out>.metrics.txt``);
4. after the benchmark completes, scrape once more and check the final
   ``scheduler.service_seconds`` p95 agrees with the sketch quantile in
   the artifact's ``telemetry.metrics`` snapshot within the sketch's
   documented relative error (alpha = 1%, plus the exporter's own
   ``%g`` rendering) — the acceptance contract tying the live endpoint
   to the offline artifact.

The regression gate then runs separately in CI::

    python -m repro.obs.regress benchmarks/baselines/cpu_seed.json \\
        BENCH_obs_live.json
"""
from __future__ import annotations

import json
import os
import threading
import time
import urllib.request

from repro import obs
from repro.obs import exporter
from repro.obs.sketch import quantile_of_snapshot

SCRAPE_TIMEOUT_S = 600.0
POLL_S = 0.05


def _scrape(url: str) -> str:
    with urllib.request.urlopen(url + "/metrics", timeout=10) as resp:
        return resp.read().decode()


def _has_serving_traffic(parsed: dict) -> bool:
    return any(name == "scheduler_service_seconds"
               and dict(labels).get("quantile")
               for name, labels in parsed)


def run_live(out_path: str, quick: bool = True) -> dict:
    from benchmarks.serving import emit

    obs.enable()
    port = int(os.environ.get("REPRO_METRICS_PORT", "0") or 0)
    srv = exporter.serve(port)
    print(f"# exporter up at {srv.url}/metrics", flush=True)

    result: dict = {}
    errors: list[BaseException] = []

    def _bench():
        try:
            result.update(emit(out_path, quick=quick))
        except BaseException as e:   # noqa: BLE001 — re-raised below
            errors.append(e)

    bench = threading.Thread(target=_bench, name="serving-bench")
    bench.start()

    # poll until a scrape catches the run mid-flight
    mid_text = None
    deadline = time.time() + SCRAPE_TIMEOUT_S
    while time.time() < deadline and bench.is_alive():
        text = _scrape(srv.url)
        if _has_serving_traffic(exporter.parse_prometheus_text(text)):
            mid_text = text
            break
        time.sleep(POLL_S)
    bench.join(timeout=SCRAPE_TIMEOUT_S)
    if errors:
        raise errors[0]
    if mid_text is None:
        raise RuntimeError("never caught a mid-run /metrics scrape with "
                           "scheduler.service_seconds samples")
    scrape_path = out_path + ".metrics.txt"
    with open(scrape_path, "w") as f:
        f.write(mid_text)
    mid = exporter.parse_prometheus_text(mid_text)
    print(f"# mid-run scrape: {len(mid)} samples -> {scrape_path}",
          flush=True)

    # final consistency: live p95 == artifact sketch p95 (rel error <=
    # sketch alpha + the exporter's %g formatting, i.e. ~1%)
    final = exporter.parse_prometheus_text(_scrape(srv.url))
    with open(out_path) as f:
        artifact = json.load(f)
    hist = artifact["telemetry"]["metrics"]["scheduler.service_seconds"]
    checked = 0
    for s in hist["series"]:
        labels = tuple(sorted([("mode", s["labels"]["mode"]),
                               ("quantile", "0.95")]))
        live = final[("scheduler_service_seconds", labels)]
        art = quantile_of_snapshot(s["value"], 0.95)
        rel = abs(live - art) / max(art, 1e-12)
        if rel > 0.02:
            raise RuntimeError(
                f"live p95 {live} vs artifact sketch p95 {art} "
                f"(mode={s['labels']['mode']}): rel err {rel:.4f} > 0.02")
        checked += 1
    print(f"# live/artifact p95 agreement checked on {checked} series",
          flush=True)
    return result


if __name__ == "__main__":
    import sys
    run_live(sys.argv[1] if len(sys.argv) > 1 else "BENCH_obs_live.json")
