"""Shared benchmark substrate: tiny trained checkpoints (cached on disk),
quality metrics, and the row/CSV format.

Every benchmark reports rows of (name, us_per_call, derived) where
``us_per_call`` is microseconds per network function evaluation (or per
step) and ``derived`` is the benchmark's headline quantity (BLEU, NFE,
perplexity proxy, roofline seconds, ...).
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import noise as noise_lib, schedules
from repro.core.samplers import registry
from repro.data import DataConfig, DataPipeline
from repro.data.synthetic import bleu
from repro.models import Model, ModelConfig
from repro.serving import EngineConfig, GenerationEngine
from repro.training import AdamW, Trainer, checkpoint, warmup_cosine

VOCAB = 28              # 27 chars + [MASK]
SEQ = 32
CKPT_DIR = os.environ.get("REPRO_CKPT_DIR", "results/ckpts")
QUICK = os.environ.get("REPRO_BENCH_QUICK", "1") == "1"


def tiny_config(name: str, vocab: int = VOCAB) -> ModelConfig:
    return ModelConfig(
        name=name, arch_type="dense", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=256, vocab_size=vocab,
        block_pattern=("attn",) * 2, bidirectional=True)


def _train(name: str, task: str, steps: int, continuous: bool = False,
           noise_kind: str = "absorbing"):
    # absorbing models reserve a [MASK] id; multinomial models use the
    # bare 27-char vocab (paper: multinomial diffusion has no mask).
    vocab = VOCAB if noise_kind == "absorbing" else VOCAB - 1
    cfg = tiny_config(name, vocab)
    model = Model(cfg)
    sch = schedules.linear(50)
    nz = noise_lib.get(noise_kind, vocab)
    # MT benchmarks use the word-reversal variant: hard enough that the
    # tiny model stays imperfect and sampler quality differences show
    pipe = DataPipeline(DataConfig(task=task, vocab=27, seq_len=SEQ,
                                   batch=32, mt_reverse=True))
    path = os.path.join(CKPT_DIR, name)
    if os.path.exists(path + ".npz"):
        params = jax.tree.map(jnp.asarray, checkpoint.load(path))
        return model, params, pipe
    opt = AdamW(schedule=warmup_cosine(3e-3, 20, steps))
    trainer = Trainer(model, sch, nz, opt, continuous_time=continuous,
                      ckpt_path=path)
    state, _ = trainer.run(iter(pipe), steps=steps, verbose=False)
    return model, state["params"], pipe


def unconditional_model(continuous: bool = False,
                        noise_kind: str = "absorbing"):
    steps = 200 if QUICK else 600
    tag = f"uncond_{noise_kind[:5]}" + ("_c" if continuous else "")
    return _train(tag, "unconditional", steps, continuous, noise_kind)


def translation_model():
    steps = 400 if QUICK else 2000
    return _train("mt", "translation", steps)


def engine(model, params, **kw) -> GenerationEngine:
    return GenerationEngine(model, params, EngineConfig(**kw))


def available_methods(noise_kind: str | None = None) -> tuple[str, ...]:
    """Engine methods from the sampler registry — benchmark grids iterate
    this (optionally filtered by noise support) instead of hand-written
    method lists."""
    return registry.names(noise_kind)


def quality_ll(pipe, tokens) -> float:
    """Per-token log-likelihood under the true Markov chain (higher =
    better; perplexity proxy = exp(-ll))."""
    return float(pipe.lang.log_likelihood(np.asarray(tokens)))


def mt_bleu(pipe, hyp, ref) -> float:
    return bleu(np.asarray(hyp), np.asarray(ref))


def timed_generate(eng, key, batch, N, cond=None, repeats: int = 1):
    outs = []
    walls = []
    for r in range(repeats):
        out, wall = eng.generate(jax.random.fold_in(key, r), batch, N,
                                 cond=cond)
        outs.append(out)
        walls.append(wall)
    return outs[-1], float(np.min(walls))


def row(name: str, us_per_call: float, derived) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
