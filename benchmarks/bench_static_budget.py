"""Beyond-paper: static-quantile DNDM — quality vs fixed NFE budget.

The deployment-grade variant compiles to exactly K network calls; this
sweep shows quality as K grows toward |T| (the Algorithm 1 limit),
answering "how few NFEs can a fixed compiled budget afford?".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common


def run(quick: bool = True) -> list[str]:
    key = jax.random.PRNGKey(9)
    model, params, pipe = common.translation_model()
    ev = pipe.eval_batches(1)[0]
    B = 16
    cond = {"prefix_tokens": jnp.asarray(ev["src"][:B])}
    ref = ev["x0"][:B]
    rows = []
    budgets = (2, 4, 8, 16, 24) if quick else (2, 4, 8, 12, 16, 24, 32)
    for K in budgets:
        for m in ("dndm_static", "dndm_topk_static"):
            eng = common.engine(model, params, method=m, steps=50,
                                nfe_budget=K)
            out, wall = common.timed_generate(eng, key, B, common.SEQ,
                                              cond=cond, repeats=2)
            score = common.mt_bleu(pipe, out.tokens, ref)
            rows.append(common.row(
                f"static_budget/K{K}/{m}", 1e6 * wall / K,
                f"bleu={score:.2f} nfe={out.nfe} wall_s={wall:.3f}"))
    # reference: dynamic Algorithm 1 on the same checkpoint
    eng = common.engine(model, params, method="dndm_topk", steps=50)
    out, wall = eng.generate(key, B, common.SEQ, cond=cond)
    rows.append(common.row(
        "static_budget/dynamic_ref", 1e6 * wall / max(out.nfe, 1),
        f"bleu={common.mt_bleu(pipe, out.tokens, ref):.2f} "
        f"nfe={out.nfe}"))
    return rows
