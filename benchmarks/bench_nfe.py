"""Paper Tables 7/8: average NFE of DNDM vs steps T, against Theorem D.1.

NFE is a pure function of the predetermined transition-time draws, so the
T=1000 rows cost nothing: we sample tau and count unique values, plus we
verify with a real sampler run at small T.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks import common
from repro.core import schedules, transition


def run(quick: bool = True) -> list[str]:
    key = jax.random.PRNGKey(0)
    rows = []
    N = common.SEQ
    batch = 100 if not quick else 32       # paper batches 100
    for T in (25, 50, 1000):
        sch = schedules.linear(T)
        dist = transition.from_schedule(sch)
        beta = transition.beta_approx(T, 5, 3)
        for name, d in (("linear", dist), ("beta(5,3)", beta)):
            tau = transition.sample_transition_times(
                jax.random.fold_in(key, T), d, batch, N)
            per_row = np.asarray(transition.nfe_of(tau, T))
            union = len(np.unique(np.asarray(tau)))
            want = d.expected_nfe(N)
            rows.append(common.row(
                f"nfe/T{T}/{name}/per_row", 0.0,
                f"avg={per_row.mean():.2f} thmD1={want:.2f}"))
            rows.append(common.row(
                f"nfe/T{T}/{name}/batch_union", 0.0,
                f"nfe={union} vs T={T}"))
    # sanity: a real sampler run agrees with the counted NFE
    model, params, pipe = common.unconditional_model()
    eng = common.engine(model, params, method="dndm", steps=50)
    out, wall = eng.generate(key, 8, N)
    rows.append(common.row("nfe/T50/real_run", 1e6 * wall / out.nfe,
                           f"nfe={out.nfe}"))
    return rows
