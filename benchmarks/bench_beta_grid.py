"""Paper Tables 9/10: Beta(a, b) grid ablation for the transition-time
approximation (reduced grid in quick mode)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common


def run(quick: bool = True) -> list[str]:
    key = jax.random.PRNGKey(6)
    model, params, pipe = common.translation_model()
    ev = pipe.eval_batches(1)[0]
    B = 16
    src = jnp.asarray(ev["src"][:B])
    ref = ev["x0"][:B]
    cond = {"prefix_tokens": src}
    rows = []
    alphas = (3, 5) if quick else (3, 5, 7)
    betas = (3, 9, 15) if quick else (3, 5, 7, 9, 11, 13, 15, 17, 19, 21)
    for a in alphas:
        for b in betas:
            eng = common.engine(model, params, method="dndm_topk",
                                steps=50, beta=(float(a), float(b)))
            out, wall = eng.generate(key, B, common.SEQ, cond=cond)
            score = common.mt_bleu(pipe, out.tokens, ref)
            rows.append(common.row(
                f"beta_grid/a{a}/b{b}", 1e6 * wall / max(out.nfe, 1),
                f"bleu={score:.2f} nfe={out.nfe}"))
    return rows
