"""JSON perf baseline: per-method wall / NFE / tokens-per-second +
telemetry snapshot.

``python -m benchmarks.run --json BENCH_decode.json`` sweeps every
registered sampler on the tiny unconditional checkpoint and writes one
machine-readable record per method, so future PRs have a perf trajectory
to diff against instead of eyeballing CSV rows.  Compile time is
reported separately (the engine warms the jit cache before the timed
run), so the numbers track sampler execution, not tracing.

The emitter always enables the ``repro.obs`` metrics registry: each
method record carries its jit-cache hit/miss counts, and the full
metrics snapshot (decode backend selection, kernel padding waste,
scheduler occupancy from a small batched drain) is folded into the
``telemetry`` section.  Schema version 2 — documented and validated by
``repro.obs.schema`` (the CI telemetry leg runs the validator against
this file plus the ``REPRO_TRACE`` JSON-lines export).
"""
from __future__ import annotations

import json
import time

import jax

from benchmarks import common
from repro import obs

BATCH = 8
REPEATS = 2


def _measure(eng, method: str, key) -> dict:
    out, wall = common.timed_generate(eng, key, BATCH, common.SEQ,
                                      repeats=REPEATS)
    toks = BATCH * common.SEQ
    hits = obs.counter("engine.jit_cache.hits")
    misses = obs.counter("engine.jit_cache.misses")
    kind = eng.check_method(method).kind
    return {
        "noise": eng.cfg.noise_kind,
        "kind": kind,
        "wall_seconds": round(wall, 6),
        "compile_seconds": round(out.aux.get("compile_seconds", 0.0), 6),
        "nfe": int(out.nfe),
        "tokens_per_second": round(toks / wall, 1),
        "us_per_nfe": round(wall / max(out.nfe, 1) * 1e6, 1),
        "metrics": {
            "jit_cache_hits": int(hits.value(method=method, kind=kind)),
            "jit_cache_misses": int(misses.value(method=method, kind=kind)),
        },
    }


def _scheduler_drain(model, params, steps: int) -> None:
    """Small batched drain so the telemetry snapshot includes the
    scheduler-layer series (occupancy, padded rows, queue depth)."""
    from repro.serving.scheduler import BatchScheduler
    eng = common.engine(model, params, method="dndm_static", steps=steps,
                        nfe_budget=min(steps, common.SEQ // 2))
    sched = BatchScheduler(eng, max_batch=4, bucket_len=common.SEQ)
    for _ in range(3):                  # 3 requests -> bucket of 4
        sched.submit(common.SEQ)
    sched.run()


def emit(path: str, quick: bool = True) -> dict:
    """Write the per-method baseline JSON; returns the record."""
    obs.enable()                        # --json implies metrics on
    steps = 16 if quick else 50
    record: dict = {
        "schema": 2,
        "jax_backend": jax.default_backend(),
        "quick": quick,
        "config": {"batch": BATCH, "seq": common.SEQ, "steps": steps},
        "methods": {},
    }
    key = jax.random.PRNGKey(0)
    models = {}
    # absorbing first: methods supporting both noise kinds are measured
    # once, on the absorbing checkpoint; multinomial-only methods (ddim)
    # ride the multinomial one.
    for noise_kind in ("absorbing", "multinomial"):
        for method in common.available_methods(noise_kind):
            if method in record["methods"]:
                continue
            if noise_kind not in models:
                models[noise_kind] = common.unconditional_model(
                    noise_kind=noise_kind)
            model, params, _ = models[noise_kind]
            eng = common.engine(model, params, method=method, steps=steps,
                                noise_kind=noise_kind,
                                nfe_budget=min(steps, common.SEQ // 2))
            t0 = time.time()
            record["methods"][method] = _measure(eng, method,
                                                 jax.random.fold_in(key, 1))
            print(f"# baseline {method}: {time.time() - t0:.1f}s",
                  flush=True)
    _scheduler_drain(*models["absorbing"][:2], steps)
    record["telemetry"] = {
        "enabled": obs.enabled(),
        "trace": obs.tracing.sink_path(),
        "metrics": obs.snapshot(),
    }
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
        f.write("\n")
    # mirror the final snapshot into the trace (if REPRO_TRACE is set) so
    # the JSONL round-trips through repro.obs.schema on its own
    obs.write_metrics_record()
    print(f"# baseline written to {path}", flush=True)
    return record
