"""JSON perf baseline: per-method wall / NFE / tokens-per-second.

``python benchmarks/run.py --json BENCH_decode.json`` sweeps every
registered sampler on the tiny unconditional checkpoint and writes one
machine-readable record per method, so future PRs have a perf trajectory
to diff against instead of eyeballing CSV rows.  Compile time is
reported separately (the engine warms the jit cache before the timed
run), so the numbers track sampler execution, not tracing.
"""
from __future__ import annotations

import json
import time

import jax

from benchmarks import common

BATCH = 8
REPEATS = 2


def _measure(eng, method: str, key) -> dict:
    out, wall = common.timed_generate(eng, key, BATCH, common.SEQ,
                                      repeats=REPEATS)
    toks = BATCH * common.SEQ
    return {
        "noise": eng.cfg.noise_kind,
        "kind": eng.check_method(method).kind,
        "wall_seconds": round(wall, 6),
        "compile_seconds": round(out.aux.get("compile_seconds", 0.0), 6),
        "nfe": int(out.nfe),
        "tokens_per_second": round(toks / wall, 1),
        "us_per_nfe": round(wall / max(out.nfe, 1) * 1e6, 1),
    }


def emit(path: str, quick: bool = True) -> dict:
    """Write the per-method baseline JSON; returns the record."""
    steps = 16 if quick else 50
    record: dict = {
        "schema": 1,
        "jax_backend": jax.default_backend(),
        "quick": quick,
        "config": {"batch": BATCH, "seq": common.SEQ, "steps": steps},
        "methods": {},
    }
    key = jax.random.PRNGKey(0)
    models = {}
    # absorbing first: methods supporting both noise kinds are measured
    # once, on the absorbing checkpoint; multinomial-only methods (ddim)
    # ride the multinomial one.
    for noise_kind in ("absorbing", "multinomial"):
        for method in common.available_methods(noise_kind):
            if method in record["methods"]:
                continue
            if noise_kind not in models:
                models[noise_kind] = common.unconditional_model(
                    noise_kind=noise_kind)
            model, params, _ = models[noise_kind]
            eng = common.engine(model, params, method=method, steps=steps,
                                noise_kind=noise_kind,
                                nfe_budget=min(steps, common.SEQ // 2))
            t0 = time.time()
            record["methods"][method] = _measure(eng, method,
                                                 jax.random.fold_in(key, 1))
            print(f"# baseline {method}: {time.time() - t0:.1f}s",
                  flush=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# baseline written to {path}", flush=True)
    return record
