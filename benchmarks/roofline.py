"""Deliverable (g): roofline table from the dry-run artifacts.

Reads results/dryrun/*.json and emits, per (arch x shape x mesh):
compute / memory / collective seconds, the dominant term, MODEL_FLOPS,
the useful-compute ratio, and a one-line recommendation for the dominant
term.  Used both as a benchmark (CSV rows) and by EXPERIMENTS.md.
"""
from __future__ import annotations

import glob
import json
import os

RECOMMEND = {
    "memory": ("switch naive S^2 attention to the blocked/flash kernel, "
               "keep activations bf16, recheck remat policy"),
    "compute": ("raise arithmetic intensity: larger per-chip batch or "
                "reduce remat recompute; check useful_ratio for waste"),
    "collective": ("reshard to cut cross-chip traffic: expert-parallel "
                   "via shard_map, overlap DP all-reduce, 2D sharding "
                   "of the giant embedding"),
}


def load_records(out_dir: str = "results/dryrun") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def table(recs: list[dict]) -> str:
    hdr = (f"{'arch':<26} {'shape':<12} {'mesh':<10} {'dom':<10} "
           f"{'compute_s':>10} {'memory_s':>10} {'coll_s':>10} "
           f"{'useful':>7} {'status'}")
    lines = [hdr, "-" * len(hdr)]
    for r in recs:
        if r.get("status") != "ok":
            lines.append(f"{r['arch']:<26} {r['shape']:<12} "
                         f"{r['mesh']:<10} {'-':<10} {'-':>10} {'-':>10} "
                         f"{'-':>10} {'-':>7} ERROR: "
                         f"{r.get('error', '?')[:60]}")
            continue
        rf = r["roofline"]
        lines.append(
            f"{r['arch']:<26} {r['shape']:<12} {r['mesh']:<10} "
            f"{rf['dominant']:<10} {rf['compute_s']:>10.3e} "
            f"{rf['memory_s']:>10.3e} {rf['collective_s']:>10.3e} "
            f"{rf['useful_ratio']:>7.3f} ok")
    return "\n".join(lines)


def run(quick: bool = True) -> list[str]:
    rows = []
    for r in load_records():
        if r.get("status") != "ok":
            rows.append(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
                        f"0.0,ERROR {r.get('error', '')[:80]}")
            continue
        rf = r["roofline"]
        dom = rf["dominant"]
        rows.append(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
            f"{max(rf['compute_s'], rf['memory_s'], rf['collective_s']) * 1e6:.1f},"
            f"dom={dom} c={rf['compute_s']:.3e} m={rf['memory_s']:.3e} "
            f"x={rf['collective_s']:.3e} useful={rf['useful_ratio']:.3f} "
            f"fix: {RECOMMEND[dom][:60]}")
    return rows


if __name__ == "__main__":
    print(table(load_records()))
