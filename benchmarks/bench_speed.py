"""Paper Fig. 1/4 + the timing columns of Tables 2/3: wall-clock vs
sampling steps for baselines (linear in T) and DNDM (nearly flat).
"""
from __future__ import annotations

import jax

from benchmarks import common


def run(quick: bool = True) -> list[str]:
    key = jax.random.PRNGKey(1)
    model, params, pipe = common.unconditional_model()
    rows = []
    B, N = 8, common.SEQ
    steps_list = (10, 25, 50) if quick else (10, 25, 50, 200, 1000)
    methods = ("d3pm", "rdm_k", "dndm", "dndm_topk")
    for steps in steps_list:
        for m in methods:
            eng = common.engine(model, params, method=m, steps=steps)
            out, wall = common.timed_generate(eng, key, B, N, repeats=2)
            rows.append(common.row(
                f"speed/T{steps}/{m}", 1e6 * wall / max(out.nfe, 1),
                f"wall_s={wall:.3f} nfe={out.nfe}"))
    # DNDM at T=1000 stays cheap even in quick mode (NFE ~ 40)
    for m in ("dndm", "dndm_topk"):
        eng = common.engine(model, params, method=m, steps=1000)
        out, wall = common.timed_generate(eng, key, B, N, repeats=2)
        rows.append(common.row(
            f"speed/T1000/{m}", 1e6 * wall / max(out.nfe, 1),
            f"wall_s={wall:.3f} nfe={out.nfe}"))
    return rows
