"""Paper Tables 2/3: conditional generation (synthetic MT) — BLEU + time
for RDM / RDM-k vs DNDM / DNDM-k across step counts, with the
continuous-time (infinity) rows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common


def run(quick: bool = True) -> list[str]:
    key = jax.random.PRNGKey(2)
    model, params, pipe = common.translation_model()
    ev = pipe.eval_batches(1)[0]
    B = 16 if quick else 64
    src = jnp.asarray(ev["src"][:B])
    ref = ev["x0"][:B]
    cond = {"prefix_tokens": src}
    rows = []
    steps_list = (25, 50) if quick else (25, 50, 1000)
    methods = ("rdm", "rdm_k", "dndm", "dndm_topk")
    for steps in steps_list:
        for m in methods:
            eng = common.engine(model, params, method=m, steps=steps,
                                beta=(5, 3) if "dndm" in m else None)
            out, wall = eng.generate(key, B, common.SEQ, cond=cond)
            score = common.mt_bleu(pipe, out.tokens, ref)
            rows.append(common.row(
                f"quality/T{steps}/{m}", 1e6 * wall / max(out.nfe, 1),
                f"bleu={score:.2f} nfe={out.nfe} wall_s={wall:.2f}"))
    # infinity rows (DNDM-C)
    for m in ("dndm_c", "dndm_c_topk"):
        eng = common.engine(model, params, method=m, steps=50,
                            beta=(17, 4))
        out, wall = eng.generate(key, B, common.SEQ, cond=cond)
        score = common.mt_bleu(pipe, out.tokens, ref)
        rows.append(common.row(
            f"quality/Tinf/{m}", 1e6 * wall / max(out.nfe, 1),
            f"bleu={score:.2f} nfe={out.nfe} wall_s={wall:.2f}"))
    return rows
