"""Regenerate the data-driven sections of EXPERIMENTS.md from
results/dryrun/*.json and results/perf/*.json.

    PYTHONPATH=src python -m benchmarks.report > /tmp/sections.md
"""
from __future__ import annotations

import glob
import json
import os


def _fmt(v, w=10):
    return f"{v:>{w}.3e}" if isinstance(v, float) else f"{v:>{w}}"


def dryrun_table(out_dir="results/dryrun") -> str:
    lines = ["| arch | shape | mesh | params (tot/act) | arg GB | temp GB "
             "| coll GB | #coll |",
             "|---|---|---|---|---|---|---|---|"]
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        r = json.load(open(p))
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"ERROR {r.get('error','')[:60]} | | | | |")
            continue
        m = r["memory"]
        cb = sum(v for k, v in r["collectives"].items() if k != "count")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['params_total']/1e9:.2f}B/{r['params_active']/1e9:.2f}B | "
            f"{m['argument_bytes']/1e9:.2f} | {m['temp_bytes']/1e9:.1f} | "
            f"{cb/1e9:.2f} | {r['collectives']['count']} |")
    return "\n".join(lines)


def roofline_table(out_dir="results/dryrun", mesh="single_pod") -> str:
    lines = ["| arch | shape | compute_s | memory_s | collective_s | "
             "dominant | MODEL_FLOPS | useful | corr_flops |",
             "|---|---|---|---|---|---|---|---|---|"]
    for p in sorted(glob.glob(os.path.join(out_dir, f"*__{mesh}.json"))):
        r = json.load(open(p))
        if r.get("status") != "ok":
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3e} | "
            f"{rf['memory_s']:.3e} | {rf['collective_s']:.3e} | "
            f"**{rf['dominant']}** | {rf['model_flops']:.3e} | "
            f"{rf['useful_ratio']:.3f} | "
            f"{rf['scan_correction_flops']:.2e} |")
    return "\n".join(lines)


def perf_table(out_dir="results/perf") -> str:
    lines = ["| pair | iteration | compute_s | memory_s | collective_s | "
             "dominant | useful |",
             "|---|---|---|---|---|---|---|"]
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        r = json.load(open(p))
        name = os.path.basename(p)[:-5]
        if r.get("status") != "ok":
            lines.append(f"| {name} | | ERROR {r.get('error','')[:80]} "
                         f"| | | | |")
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} x {r['shape']} | {r.get('tag','')} | "
            f"{rf['compute_s']:.3e} | {rf['memory_s']:.3e} | "
            f"{rf['collective_s']:.3e} | {rf['dominant']} | "
            f"{rf['useful_ratio']:.3f} |")
    return "\n".join(lines)


def main():
    print("## Dry-run artifact table\n")
    print(dryrun_table())
    print("\n## Roofline (single-pod)\n")
    print(roofline_table(mesh="single_pod"))
    print("\n## Roofline (multi-pod)\n")
    print(roofline_table(mesh="multi_pod"))
    print("\n## Perf iterations\n")
    print(perf_table())


if __name__ == "__main__":
    main()
