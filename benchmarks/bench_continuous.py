"""Paper Tables 11/12 + App. G.1: discrete vs continuous sampling, and
continuous *training* followed by continuous sampling."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks import common


def run(quick: bool = True) -> list[str]:
    key = jax.random.PRNGKey(7)
    rows = []
    # discrete-trained checkpoint
    model, params, pipe = common.unconditional_model(continuous=False)
    for m, steps in (("dndm", 50), ("dndm", 1000), ("dndm_c", 0)):
        eng = common.engine(model, params,
                            method=m, steps=steps or 50,
                            beta=(17, 4) if m == "dndm_c" else None)
        out, wall = eng.generate(key, 8, common.SEQ)
        ll = common.quality_ll(pipe, out.tokens)
        label = "inf" if m == "dndm_c" else str(steps)
        rows.append(common.row(
            f"continuous/discrete_train/T{label}", 1e6 * wall / out.nfe,
            f"ppl_proxy={np.exp(-ll):.2f} nfe={out.nfe}"))
    # continuous-trained checkpoint (App. G.1 Table 12)
    model_c, params_c, pipe_c = common.unconditional_model(continuous=True)
    eng = common.engine(model_c, params_c, method="dndm_c", steps=50,
                        beta=(17, 4))
    out, wall = eng.generate(key, 8, common.SEQ)
    ll = common.quality_ll(pipe_c, out.tokens)
    rows.append(common.row(
        "continuous/continuous_train/Tinf", 1e6 * wall / out.nfe,
        f"ppl_proxy={np.exp(-ll):.2f} nfe={out.nfe}"))
    return rows
