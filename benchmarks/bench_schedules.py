"""Paper Table 5 + App. C: transition-time schedule ablation —
cosine / cosine^2 / linear alpha / Beta for DNDM(-k), BLEU + avg NFE.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import schedules, transition
from repro.serving import EngineConfig, GenerationEngine


def run(quick: bool = True) -> list[str]:
    key = jax.random.PRNGKey(4)
    model, params, pipe = common.translation_model()
    ev = pipe.eval_batches(1)[0]
    B = 16
    src = jnp.asarray(ev["src"][:B])
    ref = ev["x0"][:B]
    cond = {"prefix_tokens": src}
    T = 50 if quick else 1000
    rows = []
    scheds: dict = {
        "cosine": None, "cosine_sq": None, "linear": None,
        "beta(5,3)": (5, 3),
    }
    for m in ("dndm", "dndm_topk"):
        for name, beta in scheds.items():
            ec = EngineConfig(method=m, steps=T,
                              schedule=name if beta is None else "linear",
                              beta=beta)
            eng = GenerationEngine(model, params, ec)
            out, wall = eng.generate(key, B, common.SEQ, cond=cond)
            score = common.mt_bleu(pipe, out.tokens, ref)
            rows.append(common.row(
                f"schedule/{m}/{name}", 1e6 * wall / max(out.nfe, 1),
                f"bleu={score:.2f} nfe={out.nfe}"))
    return rows
