"""Paper Table 13 (App. G.2): Mask-Predict baseline vs DNDM-Absorb at
matched NFE."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common


def run(quick: bool = True) -> list[str]:
    key = jax.random.PRNGKey(8)
    model, params, pipe = common.translation_model()
    ev = pipe.eval_batches(1)[0]
    B = 16
    src = jnp.asarray(ev["src"][:B])
    ref = ev["x0"][:B]
    cond = {"prefix_tokens": src}
    rows = []
    for mp_iters, dndm_steps in ((10, 25), (15, 50)):
        eng = common.engine(model, params, method="mask_predict",
                            steps=mp_iters)
        out, wall = eng.generate(key, B, common.SEQ, cond=cond)
        rows.append(common.row(
            f"maskpredict/iters{mp_iters}", 1e6 * wall / out.nfe,
            f"bleu={common.mt_bleu(pipe, out.tokens, ref):.2f} "
            f"nfe={out.nfe}"))
        for m in ("dndm", "dndm_topk"):
            eng = common.engine(model, params, method=m, steps=dndm_steps)
            out, wall = eng.generate(key, B, common.SEQ, cond=cond)
            rows.append(common.row(
                f"maskpredict/{m}_T{dndm_steps}", 1e6 * wall / out.nfe,
                f"bleu={common.mt_bleu(pipe, out.tokens, ref):.2f} "
                f"nfe={out.nfe}"))
    return rows
