"""Beyond-paper: Remark 3.5 made empirical — discrete DDIM (strided,
per-step stochastic) vs DNDM (predetermined transition times) at
MATCHED NFE on multinomial diffusion."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks import common
from repro.core import schedules
from repro.core.samplers import SamplerConfig, ddim, dndm
from repro.core import transition


def run(quick: bool = True) -> list[str]:
    key = jax.random.PRNGKey(10)
    model, params, pipe = common.unconditional_model(
        noise_kind="multinomial")
    from repro.core.noise import multinomial
    nz = multinomial(model.cfg.vocab_size)
    fn = model.denoise_fn(params)
    T = 100
    sch = schedules.linear(T)
    dist = transition.from_schedule(sch)
    B = 8
    rows = []
    cfgs = SamplerConfig()
    for stride in (2, 4) if quick else (1, 2, 4, 8):
        out = ddim.sample(key, fn, nz, sch, B, common.SEQ, stride=stride,
                          cfg=cfgs)
        ll = common.quality_ll(pipe, out.tokens)
        rows.append(common.row(
            f"ddim/stride{stride}", 0.0,
            f"ll={ll:.2f} nfe={out.nfe}"))
    out = dndm.sample(key, fn, nz, dist, B, common.SEQ, cfg=cfgs)
    ll = common.quality_ll(pipe, out.tokens)
    rows.append(common.row("ddim/dndm_ref", 0.0,
                           f"ll={ll:.2f} nfe={out.nfe}"))
    return rows
