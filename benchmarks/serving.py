"""Poisson-arrival serving benchmark: drain-mode vs continuous batching.

Replays the same Poisson arrival process (mixed request lengths,
independent per-request tau sets — ``shared_tau=False``, the honest
serving workload) through :class:`BatchScheduler` (drain mode) and
:class:`ContinuousScheduler` (NFE-aware continuous batching) and emits a
schema-2 ``"kind": "serving"`` JSON record with per-mode p50/p95 request
latency, throughput and aggregate NFE (batched network calls), validated
by ``repro.obs.schema``.

The comparison this exists to witness: with independent tau sets a drain
batch walks the *union* of its rows' transition times, while the
continuous scheduler advances each row along its own predetermined
schedule — aggregate NFE drops to the per-cohort ``max`` and the no-op
steps show up in ``scheduler.steps_skipped``.  The arrival rate is
auto-scaled from a measured per-call wall to slightly oversubscribe the
batch (the saturated regime where the NFE saving converts to
throughput); each mode is driven ``REPEATS`` times over the same
arrival tape and the minimum-wall run is reported, filtering OS
scheduling jitter out of the sub-second walls.

``python -m benchmarks.run --serving BENCH_serving.json`` (the CI
``serving`` leg runs this on CPU).
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from benchmarks import common
from repro import obs
from repro.serving.scheduler import BatchScheduler, ContinuousScheduler

MAX_BATCH = 8
METHOD = "dndm"         # host-loop DNDM: data-dependent NFE, stepwise-capable
OCCUPANCY = 1.6         # arrival-rate target: oversubscribed => saturated batch
REPEATS = 5             # interleaved per-mode drives; min wall reported


def _workload(n: int, rate: float, seed: int = 0):
    """Poisson arrival offsets (seconds) + mixed request lengths."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
    lengths = rng.integers(common.SEQ // 2, common.SEQ + 1, size=n)
    return arrivals, lengths


def _percentiles(done) -> dict:
    lat = np.asarray([r.t_done - r.t_submit for r in done.values()])
    return {"latency_p50_s": round(float(np.percentile(lat, 50)), 6),
            "latency_p95_s": round(float(np.percentile(lat, 95)), 6)}


def _drive(sched, arrivals, lengths, pump: bool):
    """Feed the arrival process in wall-clock time; returns wall seconds.

    Drain mode runs a full queue drain whenever work is queued (a batch
    launched now cannot admit later arrivals — the latency cost under
    measurement); continuous mode issues one batched step per loop
    iteration, admitting whatever has arrived by then.
    """
    n = len(arrivals)
    i = 0
    t0 = time.time()
    while len(sched.done) < n:
        now = time.time() - t0
        while i < n and arrivals[i] <= now:
            sched.submit(int(lengths[i]))
            i += 1
        if pump:
            busy = sched.pump()
        else:
            busy = bool(sched.queue)
            if busy:
                sched.run()
        if not busy and i < n:
            time.sleep(max(min(arrivals[i] - (time.time() - t0), 0.002),
                           0.0))
    return time.time() - t0


def _aggregate_nfe_drain(done) -> int:
    """Each drained batch pays its NFE once — count batches, not rows."""
    seen, agg = set(), 0
    for r in done.values():
        k = (r.t_admit, r.t_done, r.batch_size)
        if k not in seen:
            seen.add(k)
            agg += r.nfe
    return agg


def _solo_parity(eng, done, check: int = 3) -> bool:
    """Continuous-mode acceptance: replaying a request's key solo must
    reproduce its tokens (batch-shape-invariance caveats aside, dndm's
    argmax decode is robust — checked bitwise here)."""
    for r in list(done.values())[:check]:
        solo, _ = eng.generate(r.key, 1, common.SEQ, method=r.method)
        if not (np.asarray(solo.tokens)[0][: r.length] == r.result).all():
            return False
    return True


def emit(path: str, quick: bool = True) -> dict:
    obs.enable()
    steps = 24 if quick else 64
    n_requests = 24 if quick else 64
    model, params, _ = common.unconditional_model()
    eng = common.engine(model, params, method=METHOD, steps=steps,
                        shared_tau=False)

    # warm every compiled shape out of the measured window: drain buckets
    # (powers of two up to MAX_BATCH) + the continuous rolling batch
    key = jax.random.PRNGKey(0)
    b = 1
    while b <= MAX_BATCH:
        eng.generate(jax.random.fold_in(key, b), b, common.SEQ)
        b *= 2
    warm = ContinuousScheduler(eng, max_batch=MAX_BATCH,
                               bucket_len=common.SEQ, seed=99)
    for _ in range(2):
        warm.submit(common.SEQ)
    warm.run()

    # auto-scale the arrival rate past batch saturation: service rate of
    # one request ~= E[NFE] calls at the measured per-call wall
    out, wall = eng.generate(jax.random.fold_in(key, 17), MAX_BATCH,
                             common.SEQ)
    per_call = wall / max(out.nfe, 1)
    e_nfe = eng.runtime().dist.expected_nfe(common.SEQ)
    rate = OCCUPANCY * MAX_BATCH / (e_nfe * per_call)
    arrivals, lengths = _workload(n_requests, rate)

    record: dict = {
        "schema": 2,
        "kind": "serving",
        "jax_backend": jax.default_backend(),
        "quick": quick,
        "config": {"max_batch": MAX_BATCH, "seq": common.SEQ,
                   "steps": steps, "requests": n_requests,
                   "method": METHOD, "shared_tau": False,
                   "arrival_rate_rps": round(float(rate), 3)},
        "modes": {},
    }

    # interleave the two modes' repeats so a transient CPU-noise burst
    # cannot land entirely inside one mode's measurement window
    drain = wall_d = None
    cont = wall_c = midflight = None
    for _ in range(REPEATS):
        sched = BatchScheduler(eng, max_batch=MAX_BATCH,
                               bucket_len=common.SEQ, seed=1)
        w = _drive(sched, arrivals, lengths, pump=False)
        if wall_d is None or w < wall_d:
            drain, wall_d = sched, w

        mid0 = obs.counter("scheduler.admissions_midflight").value(
            method=METHOD)
        sched = ContinuousScheduler(eng, max_batch=MAX_BATCH,
                                    bucket_len=common.SEQ, seed=1)
        w = _drive(sched, arrivals, lengths, pump=True)
        mid = obs.counter("scheduler.admissions_midflight").value(
            method=METHOD) - mid0
        if wall_c is None or w < wall_c:
            cont, wall_c, midflight = sched, w, mid

    record["modes"]["drain"] = {
        "wall_seconds": round(wall_d, 4),
        "aggregate_nfe": _aggregate_nfe_drain(drain.done),
        "throughput_rps": round(n_requests / wall_d, 3),
        **_percentiles(drain.done),
    }
    skipped = sum(r.steps_skipped for r in cont.done.values())
    record["modes"]["continuous"] = {
        "wall_seconds": round(wall_c, 4),
        "aggregate_nfe": cont.total_calls,
        "throughput_rps": round(n_requests / wall_c, 3),
        "steps_skipped": int(skipped),
        "admissions_midflight": int(midflight),
        **_percentiles(cont.done),
    }

    d, c = record["modes"]["drain"], record["modes"]["continuous"]
    record["comparison"] = {
        "nfe_ratio": round(c["aggregate_nfe"] / max(d["aggregate_nfe"], 1),
                           4),
        "throughput_ratio": round(c["throughput_rps"]
                                  / max(d["throughput_rps"], 1e-9), 4),
        "fewer_nfe": bool(c["aggregate_nfe"] < d["aggregate_nfe"]),
        "solo_parity": _solo_parity(eng, cont.done),
    }
    record["telemetry"] = {
        "enabled": obs.enabled(),
        "trace": obs.tracing.sink_path(),
        "metrics": obs.snapshot(),
    }
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
        f.write("\n")
    obs.write_metrics_record()
    print(f"# serving benchmark written to {path}: "
          f"nfe {c['aggregate_nfe']} vs {d['aggregate_nfe']} (drain), "
          f"throughput x{record['comparison']['throughput_ratio']}, "
          f"parity={record['comparison']['solo_parity']}", flush=True)
    return record
