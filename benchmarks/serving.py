"""Poisson-arrival serving benchmark: drain-mode vs continuous batching.

Replays the same Poisson arrival process (mixed request lengths,
independent per-request tau sets — ``shared_tau=False``, the honest
serving workload) through :class:`BatchScheduler` (drain mode) and
:class:`ContinuousScheduler` (NFE-aware continuous batching) and emits a
schema-2 ``"kind": "serving"`` JSON record with per-mode p50/p95 request
latency, throughput and aggregate NFE (batched network calls), validated
by ``repro.obs.schema``.

The comparison this exists to witness: with independent tau sets a drain
batch walks the *union* of its rows' transition times, while the
continuous scheduler advances each row along its own predetermined
schedule — aggregate NFE drops to the per-cohort ``max`` and the no-op
steps show up in ``scheduler.steps_skipped``.  The arrival rate is
auto-scaled from a measured per-call wall to slightly oversubscribe the
batch (the saturated regime where the NFE saving converts to
throughput); each mode is driven ``REPEATS`` times over the same
arrival tape and the minimum-wall run is reported, filtering OS
scheduling jitter out of the sub-second walls.

``python -m benchmarks.run --serving BENCH_serving.json`` (the CI
``serving`` leg runs this on CPU).  ``--serving-registry`` runs
:func:`emit_registry` instead: the same drain-vs-continuous drive with
requests cycling over EVERY method in the sampler registry (a second
multinomial engine covers the ddim remainder), witnessing that the whole
registry serves through ``ContinuousScheduler``.
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from benchmarks import common
from repro import obs
from repro.obs import slo as slo_lib
from repro.serving.scheduler import BatchScheduler, ContinuousScheduler

MAX_BATCH = 8
METHOD = "dndm"         # host-loop DNDM: data-dependent NFE, stepwise-capable
OCCUPANCY = 1.6         # arrival-rate target: oversubscribed => saturated batch
REPEATS = 5             # interleaved per-mode drives; min wall reported


def _workload(n: int, rate: float, seed: int = 0):
    """Poisson arrival offsets (seconds) + mixed request lengths."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
    lengths = rng.integers(common.SEQ // 2, common.SEQ + 1, size=n)
    return arrivals, lengths


def _percentiles(done) -> dict:
    lat = np.asarray([r.t_done - r.t_submit for r in done.values()])
    return {"latency_p50_s": round(float(np.percentile(lat, 50)), 6),
            "latency_p95_s": round(float(np.percentile(lat, 95)), 6),
            "latency_p99_s": round(float(np.percentile(lat, 99)), 6)}


def _drive(sched, arrivals, lengths, pump: bool, methods=None):
    """Feed the arrival process in wall-clock time; returns wall seconds.

    Drain mode runs a full queue drain whenever work is queued (a batch
    launched now cannot admit later arrivals — the latency cost under
    measurement); continuous mode issues one batched step per loop
    iteration, admitting whatever has arrived by then.  ``methods``
    optionally cycles request i onto ``methods[i % len(methods)]`` (the
    full-registry leg); None keeps the engine's configured method.
    """
    n = len(arrivals)
    i = 0
    t0 = time.time()
    while len(sched.done) < n:
        now = time.time() - t0
        while i < n and arrivals[i] <= now:
            sched.submit(int(lengths[i]),
                         method=methods[i % len(methods)] if methods
                         else None)
            i += 1
        if pump:
            busy = sched.pump()
        else:
            busy = bool(sched.queue)
            if busy:
                sched.run()
        if not busy and i < n:
            time.sleep(max(min(arrivals[i] - (time.time() - t0), 0.002),
                           0.0))
    return time.time() - t0


def _aggregate_nfe_drain(done) -> int:
    """Each drained batch pays its NFE once — count batches, not rows."""
    seen, agg = set(), 0
    for r in done.values():
        k = (r.t_admit, r.t_done, r.batch_size)
        if k not in seen:
            seen.add(k)
            agg += r.nfe
    return agg


def _solo_parity(eng, done, check: int = 3, methods=None) -> bool:
    """Continuous-mode acceptance: replaying a request's key solo must
    reproduce its tokens.  ``methods`` restricts the spot-check to the
    argmax-decode DNDM family on mixed-method workloads — bitwise parity
    under a *real* transformer needs batch-shape-robust decoding (the
    score-*ranked* methods are covered bitwise by the elementwise-model
    tests in tests/test_scheduler.py)."""
    reqs = [r for r in done.values()
            if methods is None or r.method in methods]
    for r in reqs[:check]:
        solo, _ = eng.generate(r.key, 1, common.SEQ, method=r.method)
        if not (np.asarray(solo.tokens)[0][: r.length] == r.result).all():
            return False
    return True


def emit(path: str, quick: bool = True) -> dict:
    obs.enable()
    steps = 24 if quick else 64
    n_requests = 24 if quick else 64
    model, params, _ = common.unconditional_model()
    eng = common.engine(model, params, method=METHOD, steps=steps,
                        shared_tau=False)

    # warm every compiled shape out of the measured window: drain buckets
    # (powers of two up to MAX_BATCH) + the continuous rolling batch
    key = jax.random.PRNGKey(0)
    b = 1
    while b <= MAX_BATCH:
        eng.generate(jax.random.fold_in(key, b), b, common.SEQ)
        b *= 2
    warm = ContinuousScheduler(eng, max_batch=MAX_BATCH,
                               bucket_len=common.SEQ, seed=99)
    for _ in range(2):
        warm.submit(common.SEQ)
    warm.run()

    # auto-scale the arrival rate past batch saturation: service rate of
    # one request ~= E[NFE] calls at the measured per-call wall
    out, wall = eng.generate(jax.random.fold_in(key, 17), MAX_BATCH,
                             common.SEQ)
    per_call = wall / max(out.nfe, 1)
    e_nfe = eng.runtime().dist.expected_nfe(common.SEQ)
    rate = OCCUPANCY * MAX_BATCH / (e_nfe * per_call)
    arrivals, lengths = _workload(n_requests, rate)

    # score the measured traffic against default serving budgets (unless
    # REPRO_SLO already configured some): the full-drain service time
    # bounds any sane request latency, and the per-request NFE can never
    # exceed the step grid — breaches land in scheduler.slo_breaches and
    # the burn summary below
    if not slo_lib.active():
        slo_lib.configure([
            slo_lib.Budget("latency", round(e_nfe * per_call * 4, 3),
                           objective=0.95),
            slo_lib.Budget("nfe", steps, objective=1.0)])

    record: dict = {
        "schema": 2,
        "kind": "serving",
        "jax_backend": jax.default_backend(),
        "quick": quick,
        "config": {"max_batch": MAX_BATCH, "seq": common.SEQ,
                   "steps": steps, "requests": n_requests,
                   "method": METHOD, "shared_tau": False,
                   "arrival_rate_rps": round(float(rate), 3)},
        "modes": {},
    }

    # interleave the two modes' repeats so a transient CPU-noise burst
    # cannot land entirely inside one mode's measurement window
    drain = wall_d = None
    cont = wall_c = midflight = None
    for _ in range(REPEATS):
        sched = BatchScheduler(eng, max_batch=MAX_BATCH,
                               bucket_len=common.SEQ, seed=1)
        w = _drive(sched, arrivals, lengths, pump=False)
        if wall_d is None or w < wall_d:
            drain, wall_d = sched, w

        mid0 = obs.counter("scheduler.admissions_midflight").value(
            method=METHOD)
        sched = ContinuousScheduler(eng, max_batch=MAX_BATCH,
                                    bucket_len=common.SEQ, seed=1)
        w = _drive(sched, arrivals, lengths, pump=True)
        mid = obs.counter("scheduler.admissions_midflight").value(
            method=METHOD) - mid0
        if wall_c is None or w < wall_c:
            cont, wall_c, midflight = sched, w, mid

    record["modes"]["drain"] = {
        "wall_seconds": round(wall_d, 4),
        "aggregate_nfe": _aggregate_nfe_drain(drain.done),
        "throughput_rps": round(n_requests / wall_d, 3),
        **_percentiles(drain.done),
    }
    skipped = sum(r.steps_skipped for r in cont.done.values())
    record["modes"]["continuous"] = {
        "wall_seconds": round(wall_c, 4),
        "aggregate_nfe": cont.total_calls,
        "throughput_rps": round(n_requests / wall_c, 3),
        "steps_skipped": int(skipped),
        "admissions_midflight": int(midflight),
        **_percentiles(cont.done),
    }

    d, c = record["modes"]["drain"], record["modes"]["continuous"]
    record["comparison"] = {
        "nfe_ratio": round(c["aggregate_nfe"] / max(d["aggregate_nfe"], 1),
                           4),
        "throughput_ratio": round(c["throughput_rps"]
                                  / max(d["throughput_rps"], 1e-9), 4),
        "fewer_nfe": bool(c["aggregate_nfe"] < d["aggregate_nfe"]),
        "solo_parity": _solo_parity(eng, cont.done),
    }
    record["telemetry"] = {
        "enabled": obs.enabled(),
        "trace": obs.tracing.sink_path(),
        "slo": slo_lib.status(),
        "metrics": obs.snapshot(),
    }
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
        f.write("\n")
    obs.write_metrics_record()
    print(f"# serving benchmark written to {path}: "
          f"nfe {c['aggregate_nfe']} vs {d['aggregate_nfe']} (drain), "
          f"throughput x{record['comparison']['throughput_ratio']}, "
          f"parity={record['comparison']['solo_parity']}", flush=True)
    return record


REPEATS_REGISTRY = 2    # coverage leg: correctness first, min-wall of 2


def _mid_total(methods) -> int:
    c = obs.counter("scheduler.admissions_midflight")
    return int(sum(c.value(method=m) for m in methods))


def emit_registry(path: str, quick: bool = True) -> dict:
    """Full-registry serving leg (``--serving-registry``).

    The same Poisson drain-vs-continuous drive as :func:`emit`, but the
    arrival tape cycles requests over *every* method the sampler registry
    exposes for the engine's noise kind; ddim (multinomial-only) rides a
    second tiny engine so ``registry.names()`` is covered exactly.  The
    record is the standard schema-2 ``"kind": "serving"`` artifact with
    ``config.method = "registry"`` plus a ``coverage`` map (method ->
    requests completed in continuous mode); completion of every method is
    enforced here, not just measured.
    """
    obs.enable()
    steps = 12 if quick else 32
    model, params, _ = common.unconditional_model()
    eng = common.engine(model, params, method=METHOD, steps=steps,
                        shared_tau=False, nfe_budget=6, ddim_stride=2)
    methods = list(common.available_methods("absorbing"))
    m_model, m_params, _ = common.unconditional_model(
        noise_kind="multinomial")
    m_eng = common.engine(m_model, m_params, method="ddim", steps=steps,
                          noise_kind="multinomial", shared_tau=False,
                          nfe_budget=6, ddim_stride=2)
    m_methods = [m for m in common.available_methods("multinomial")
                 if m not in methods]
    if sorted(methods + m_methods) != list(common.available_methods()):
        raise RuntimeError("registry leg does not cover every method")

    # warm the compiled shapes out of the measured window: the rolling
    # stepwise batch per method + the drain buckets the cohorts will hit
    for sched_eng, ms in ((eng, methods), (m_eng, m_methods)):
        warm_c = ContinuousScheduler(sched_eng, max_batch=MAX_BATCH,
                                     bucket_len=common.SEQ, seed=99)
        warm_d = BatchScheduler(sched_eng, max_batch=MAX_BATCH,
                                bucket_len=common.SEQ, seed=98)
        for m in ms:
            warm_c.submit(common.SEQ, method=m)
            for _ in range(2):
                warm_d.submit(common.SEQ, method=m)
        warm_c.run()
        warm_d.run()

    key = jax.random.PRNGKey(0)
    out, wall = eng.generate(jax.random.fold_in(key, 17), MAX_BATCH,
                             common.SEQ)
    per_call = wall / max(out.nfe, 1)
    e_nfe = eng.runtime().dist.expected_nfe(common.SEQ)
    rate = OCCUPANCY * MAX_BATCH / (e_nfe * per_call)
    n_abs = (2 if quick else 4) * len(methods)
    n_rest = (1 if quick else 2) * len(m_methods)
    arrivals, lengths = _workload(n_abs, rate, seed=5)
    m_arrivals, m_lengths = _workload(max(n_rest, 1), rate, seed=6)

    drain = wall_d = None
    cont = wall_c = midflight = None
    for _ in range(REPEATS_REGISTRY):
        d1 = BatchScheduler(eng, max_batch=MAX_BATCH,
                            bucket_len=common.SEQ, seed=1)
        w = _drive(d1, arrivals, lengths, pump=False, methods=methods)
        d2 = BatchScheduler(m_eng, max_batch=MAX_BATCH,
                            bucket_len=common.SEQ, seed=2)
        w += _drive(d2, m_arrivals, m_lengths, pump=False,
                    methods=m_methods)
        if wall_d is None or w < wall_d:
            drain, wall_d = (d1, d2), w

        mid0 = _mid_total(methods + m_methods)
        c1 = ContinuousScheduler(eng, max_batch=MAX_BATCH,
                                 bucket_len=common.SEQ, seed=1)
        w = _drive(c1, arrivals, lengths, pump=True, methods=methods)
        c2 = ContinuousScheduler(m_eng, max_batch=MAX_BATCH,
                                 bucket_len=common.SEQ, seed=2)
        w += _drive(c2, m_arrivals, m_lengths, pump=True,
                    methods=m_methods)
        mid = _mid_total(methods + m_methods) - mid0
        if wall_c is None or w < wall_c:
            cont, wall_c, midflight = (c1, c2), w, mid

    cont_reqs = [r for s in cont for r in s.done.values()]
    coverage: dict[str, int] = {}
    for r in cont_reqs:
        coverage[r.method] = coverage.get(r.method, 0) + 1
    missing = set(common.available_methods()) - set(coverage)
    if missing:
        raise RuntimeError(f"continuous mode failed to serve: {missing}")

    n_requests = n_abs + max(n_rest, 1)
    record: dict = {
        "schema": 2,
        "kind": "serving",
        "jax_backend": jax.default_backend(),
        "quick": quick,
        "config": {"max_batch": MAX_BATCH, "seq": common.SEQ,
                   "steps": steps, "requests": n_requests,
                   "method": "registry",
                   "methods": sorted(coverage),
                   "shared_tau": False,
                   "arrival_rate_rps": round(float(rate), 3)},
        "coverage": coverage,
        "modes": {},
    }
    drain_reqs = [r for s in drain for r in s.done.values()]
    record["modes"]["drain"] = {
        "wall_seconds": round(wall_d, 4),
        "aggregate_nfe": sum(_aggregate_nfe_drain(s.done) for s in drain),
        "throughput_rps": round(n_requests / wall_d, 3),
        **_percentiles({i: r for i, r in enumerate(drain_reqs)}),
    }
    record["modes"]["continuous"] = {
        "wall_seconds": round(wall_c, 4),
        "aggregate_nfe": sum(s.total_calls for s in cont),
        "throughput_rps": round(n_requests / wall_c, 3),
        "steps_skipped": int(sum(r.steps_skipped for r in cont_reqs)),
        "admissions_midflight": int(midflight),
        **_percentiles({i: r for i, r in enumerate(cont_reqs)}),
    }
    d, c = record["modes"]["drain"], record["modes"]["continuous"]
    record["comparison"] = {
        "nfe_ratio": round(c["aggregate_nfe"] / max(d["aggregate_nfe"], 1),
                           4),
        "throughput_ratio": round(c["throughput_rps"]
                                  / max(d["throughput_rps"], 1e-9), 4),
        "fewer_nfe": bool(c["aggregate_nfe"] < d["aggregate_nfe"]),
        "solo_parity": (_solo_parity(eng, cont[0].done,
                                     methods=("dndm", "dndm2"))
                        and _solo_parity(m_eng, cont[1].done,
                                         methods=("dndm", "dndm2"))),
    }
    record["telemetry"] = {
        "enabled": obs.enabled(),
        "trace": obs.tracing.sink_path(),
        "slo": slo_lib.status(),
        "metrics": obs.snapshot(),
    }
    with open(path, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
        f.write("\n")
    obs.write_metrics_record()
    print(f"# registry serving benchmark written to {path}: "
          f"{len(coverage)} methods served continuously, "
          f"nfe {c['aggregate_nfe']} vs {d['aggregate_nfe']} (drain), "
          f"parity={record['comparison']['solo_parity']}", flush=True)
    return record
