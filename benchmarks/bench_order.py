"""Paper Table 6 (App. C): transition-order ablation — iid vs
left-to-right vs right-to-left position-ordered transition times.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common


def run(quick: bool = True) -> list[str]:
    key = jax.random.PRNGKey(5)
    model, params, pipe = common.translation_model()
    ev = pipe.eval_batches(1)[0]
    B = 16
    src = jnp.asarray(ev["src"][:B])
    ref = ev["x0"][:B]
    cond = {"prefix_tokens": src}
    rows = []
    for steps in ((25, 50) if quick else (25, 50, 1000)):
        for order in ("iid", "l2r", "r2l"):
            eng = common.engine(model, params, method="dndm_topk",
                                steps=steps, order=order)
            out, wall = eng.generate(key, B, common.SEQ, cond=cond)
            score = common.mt_bleu(pipe, out.tokens, ref)
            rows.append(common.row(
                f"order/T{steps}/{order}", 1e6 * wall / max(out.nfe, 1),
                f"bleu={score:.2f} nfe={out.nfe}"))
    return rows
