"""Hypothesis property tests for the continuous NFE-aware scheduler
(ISSUE 8, importorskip-guarded like tests/test_properties.py).

Random arrival orders, lengths, methods, and pump interleavings into
:class:`ContinuousScheduler` must always yield

  * exactly-once completion — every submitted request id appears in
    ``done`` exactly once, with a result of its own length;
  * solo parity — each request's tokens are bitwise identical to
    ``engine.generate(request.key, 1, N, method=...)`` (same tau set and
    per-step key stream, replayed outside the rolling batch);
  * the step-accounting invariant ``steps_executed + steps_skipped == T``
    (the skipped no-op steps are exactly the grid steps absent from the
    request's predetermined schedule; continuous-time methods have no
    grid, so they execute all N timestamps and skip nothing).

The denoiser is a *purely elementwise* fake (each row's logits depend
only on that row), so trajectories are batch-shape-invariant and the
parity assertion is exact — a real transformer mixes rows only through
XLA reduction scheduling (~1e-6 logit jitter), which is why the
real-model bitwise checks in tests/test_scheduler.py stick to the
argmax-decode dndm/dndm2 while this file samples across every stepwise
family (see METHODS; the exhaustive one-shot sweep is
tests/test_scheduler.py::test_stepwise_full_registry_solo_parity).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.serving import ContinuousScheduler, EngineConfig, GenerationEngine

VOCAB, SEQ, STEPS, ROWS = 10, 8, 6, 3
# one per stepwise family: host DNDM (Alg 1/3/4), static grid, ancestral
# baselines (d3pm / rdm-k / mask-predict) and continuous time (Alg 2)
METHODS = ("dndm", "dndm2", "dndm_topk", "dndm_static", "d3pm", "rdm_k",
           "mask_predict", "dndm_c")
CONTINUOUS = ("dndm_c", "dndm_c_topk")


class _FakeCfg:
    vocab_size = VOCAB


class _FakeModel:
    """Elementwise denoiser: logits[b, n, k] depend only on row b's own
    tokens, so batch shape cannot perturb any row's trajectory."""

    cfg = _FakeCfg()

    def init(self, key):
        return {}

    def denoise_fn(self, params, cond=None):
        def fn(x_t, t, cond_rt):
            k = jnp.arange(VOCAB, dtype=jnp.float32)
            n = jnp.arange(x_t.shape[-1], dtype=jnp.float32)
            t_ = jnp.asarray(t, jnp.float32).reshape(-1, 1, 1)
            return jnp.sin(x_t[..., None].astype(jnp.float32) * 0.37
                           + k * 1.11 + n[None, :, None] * 0.23
                           + t_ * 2.9) * 4.0
        return fn


@pytest.fixture(scope="module")
def engine():
    model = _FakeModel()
    return GenerationEngine(model, model.init(None), EngineConfig(
        method="dndm", steps=STEPS, shared_tau=False))


@given(
    requests=st.lists(
        st.tuples(st.integers(3, SEQ), st.sampled_from(METHODS),
                  st.integers(0, 2)),      # (length, method, pumps after)
        min_size=1, max_size=7),
    seed=st.integers(0, 1_000),
)
@settings(max_examples=10, deadline=None)
def test_continuous_scheduler_invariants(engine, requests, seed):
    sched = ContinuousScheduler(engine, max_batch=ROWS, bucket_len=SEQ,
                                seed=seed)
    rids = []
    for length, method, pumps in requests:
        rids.append(sched.submit(length, method=method))
        for _ in range(pumps):
            sched.pump()
    sched.run()

    # exactly-once completion
    assert sorted(sched.done) == sorted(rids)
    assert len(set(rids)) == len(rids)
    assert not sched.queue and not sched._row_req

    total_executed = 0
    for rid, (length, method, _) in zip(rids, requests):
        r = sched.done[rid]
        assert r.result is not None and r.result.shape == (length,)
        toks = np.asarray(r.result)
        assert (0 <= toks).all() and (toks < VOCAB).all()

        # step accounting: the skipped no-op steps are exactly the grid
        # steps the predetermined tau set proved unnecessary (continuous
        # time has no grid — the N timestamps ARE the schedule)
        assert r.steps_executed == len(r.plan.times)
        if method in CONTINUOUS:
            assert (r.steps_executed, r.steps_skipped) == (SEQ, 0)
        else:
            assert r.steps_executed + r.steps_skipped == STEPS
        assert r.nfe == r.steps_executed
        total_executed += r.steps_executed

        # solo parity: same key => same tau set, x_T, and per-step keys
        solo, _ = engine.generate(r.key, 1, SEQ, method=method)
        np.testing.assert_array_equal(
            np.asarray(solo.tokens)[0, :length], toks,
            err_msg=f"rid {rid} ({method}) diverged from its solo replay")

    # batching can only help: cohort calls = max over member schedules,
    # never more than the sum of solo schedules
    assert sched.total_calls <= total_executed
