"""Pallas kernel sweeps: shapes x dtypes vs pure-jnp oracles
(interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_scores import ops as ds_ops, ref as ds_ref
from repro.kernels.dndm_update import ops as dndm_ops, ref as dndm_ref
from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.ssd_scan import ops as ssd_ops, ref as ssd_ref

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("B,S,H,hd", [(1, 32, 2, 16), (2, 64, 4, 32),
                                      (1, 128, 2, 64), (2, 48, 3, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, S, H, hd, dtype, causal, key):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, H, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, H, hd), dtype)
    if causal:
        bias = jnp.where(jnp.tril(jnp.ones((S, S), bool)), 0.0, -1e9)
        bias = jnp.broadcast_to(bias, (B, S, S))
    else:
        bias = jnp.zeros((B, S, S))
    out = fa_ops.flash_attention(q, k, v, bias, block_q=16, block_k=16)
    ref = fa_ref.attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), bias).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_flash_attention_ragged_padding(key):
    """S not divisible by block => wrapper pads and un-pads correctly."""
    B, S, H, hd = 2, 37, 2, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    bias = jnp.zeros((B, S, S))
    out = fa_ops.flash_attention(q, k, v, bias, block_q=16, block_k=16)
    ref = fa_ref.attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), bias).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("B,N,K", [(1, 16, 32), (3, 40, 100),
                                   (2, 64, 257), (1, 7, 1000)])
@pytest.mark.parametrize("version", [1, 2])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dndm_update_sweep(B, N, K, version, dtype, key):
    ks = jax.random.split(key, 3)
    logits = jax.random.normal(ks[0], (B, N, K), dtype)
    x = jax.random.randint(ks[1], (B, N), 0, K)
    tau = jax.random.randint(ks[2], (B, N), 1, 20)
    for t in (1, 5, 19):
        out = dndm_ops.dndm_update(logits, x, tau, t, version=version,
                                   block_n=16, block_v=64)
        ref = dndm_ref.dndm_update_ref(logits, x, tau,
                                       jnp.asarray([t]), version=version)
        assert (np.asarray(out) == np.asarray(ref)).all()


@pytest.mark.parametrize("B,N,K", [(1, 16, 32), (3, 40, 100),
                                   (2, 64, 257), (1, 7, 1000)])
@pytest.mark.parametrize("gumbel", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_scores_sweep(B, N, K, gumbel, dtype, key):
    """Streaming (token, score) kernel vs oracle: tokens bitwise, scores
    allclose (online logsumexp), masked + temperature + both dtypes."""
    ks = jax.random.split(key, 2)
    logits = jax.random.normal(ks[0], (B, N, K), dtype)
    mask = jnp.where(jnp.arange(K) == K - 1, -1e9, 0.0)
    g = jax.random.gumbel(ks[1], (B, N, K), jnp.float32) if gumbel else None
    tok, score = ds_ops.decode_scores(logits, mask=mask, gumbel=g,
                                      temperature=0.7, block_n=16,
                                      block_v=64)
    rt, rs = ds_ref.decode_scores_ref(logits, mask=mask, gumbel=g,
                                      temperature=0.7)
    assert (np.asarray(tok) == np.asarray(rt)).all()
    np.testing.assert_allclose(np.asarray(score), np.asarray(rs),
                               atol=2e-5, rtol=2e-5)
    # rank key sanity: scores are log-probs of the chosen token
    assert (np.asarray(score) <= 1e-6).all()
    assert not (np.asarray(tok) == K - 1).any()   # masked id never decoded


@pytest.mark.parametrize("B,S,H,P,Nst,chunk", [
    (1, 16, 1, 4, 8, 4), (2, 48, 3, 8, 16, 16), (1, 64, 2, 16, 8, 32),
    (2, 33, 2, 8, 8, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(B, S, H, P, Nst, chunk, dtype, key):
    ks = jax.random.split(key, 5)
    x = (jax.random.normal(ks[0], (B, S, H, P)) * 0.5).astype(dtype)
    dtv = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = (jax.random.normal(ks[3], (B, S, Nst)) * 0.3).astype(dtype)
    Cm = (jax.random.normal(ks[4], (B, S, Nst)) * 0.3).astype(dtype)
    y_seq, _ = ssd_ref.ssd_sequential_ref(x, dtv, A, Bm, Cm)
    y_kern, _ = ssd_ops.ssd_scan(x, dtv, A, Bm, Cm, chunk=chunk)
    tol = 3e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y_kern, np.float32),
                               np.asarray(y_seq, np.float32),
                               atol=tol, rtol=tol)


def test_ssd_chunked_ref_matches_sequential(key):
    """The model's chunked implementation == the exact recurrence."""
    B, S, H, P, Nst = 2, 40, 2, 8, 16
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dtv = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, Nst)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, Nst)) * 0.3
    y_seq, s_seq = ssd_ref.ssd_sequential_ref(x, dtv, A, Bm, Cm)
    for chunk in (5, 8, 40, 64):
        y_c, s_c = ssd_ref.ssd_chunked_ref(x, dtv, A, Bm, Cm, chunk)
        np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_seq),
                                   atol=3e-5, rtol=3e-5)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_seq),
                               atol=3e-5, rtol=3e-5)
