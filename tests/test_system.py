"""End-to-end behaviour: train a tiny denoiser, then verify the paper's
central claims on it — DNDM matches baseline quality at a fraction of the
NFE, top-k improves quality, continuous sampling hits NFE == N.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import schedules, noise
from repro.data import DataConfig, DataPipeline
from repro.models import Model, ModelConfig
from repro.serving import BatchScheduler, EngineConfig, GenerationEngine
from repro.training import AdamW, Trainer, warmup_cosine

VOCAB = 28            # 27 chars + [MASK]
SEQ = 32


@pytest.fixture(scope="module")
def trained():
    cfg = ModelConfig(name="tiny", arch_type="dense", n_layers=2,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab_size=VOCAB, block_pattern=("attn",) * 2,
                      bidirectional=True)
    model = Model(cfg)
    sch = schedules.linear(50)
    nz = noise.absorbing(VOCAB)
    opt = AdamW(schedule=warmup_cosine(3e-3, 20, 150))
    pipe = DataPipeline(DataConfig(task="unconditional", vocab=27,
                                   seq_len=SEQ, batch=32))
    trainer = Trainer(model, sch, nz, opt)
    state, hist = trainer.run(iter(pipe), steps=250, verbose=False)
    assert hist[-1]["loss"] < hist[0]["loss"]
    return model, state["params"], pipe


def _quality(pipe, tokens):
    """Per-token log-likelihood under the true Markov chain."""
    return pipe.lang.log_likelihood(np.asarray(tokens))


def _random_floor(pipe, key):
    """ll of uniform-random text under the chain (the honest floor —
    sparse transition rows make this far below log(1/K))."""
    rnd = jax.random.randint(key, (16, SEQ), 0, 27)
    return pipe.lang.log_likelihood(np.asarray(rnd))


@pytest.mark.slow
def test_dndm_quality_and_nfe_vs_baseline(trained, key):
    model, params, pipe = trained
    steps = 50
    results = {}
    for method in ("d3pm", "dndm", "dndm_topk", "rdm_k"):
        eng = GenerationEngine(model, params, EngineConfig(
            method=method, steps=steps, noise_kind="absorbing"))
        out, wall = eng.generate(key, 16, SEQ)
        results[method] = {"nfe": out.nfe, "ll": _quality(pipe, out.tokens)}
    # NFE: DNDM strictly below T, baselines at T
    assert results["d3pm"]["nfe"] == steps
    assert results["rdm_k"]["nfe"] == steps
    assert results["dndm"]["nfe"] < steps
    assert results["dndm_topk"]["nfe"] < steps
    # quality: everyone beats the uniform-noise floor; DNDM within
    # tolerance of the T-step baseline (paper: quality preserved)
    ref = _random_floor(pipe, jax.random.fold_in(key, 99))
    for m, r in results.items():
        assert r["ll"] > ref + 0.1, (m, r, ref)
    # single-run stochastic generation on a 250-step model: allow
    # generous slack; the floor is ~ -24, so 1.5 nats is still tight
    assert results["dndm"]["ll"] > results["d3pm"]["ll"] - 1.5
    assert results["dndm_topk"]["ll"] > results["dndm"]["ll"] - 0.5


@pytest.mark.slow
def test_dndm_c_infinite_step(trained, key):
    model, params, pipe = trained
    eng = GenerationEngine(model, params, EngineConfig(
        method="dndm_c", steps=50, noise_kind="absorbing", beta=(17, 4)))
    out, _ = eng.generate(key, 8, SEQ)
    assert out.nfe == SEQ                      # continuous limit: NFE == N
    floor = _random_floor(pipe, jax.random.fold_in(key, 98))
    assert _quality(pipe, out.tokens) > floor + 0.1


@pytest.mark.slow
def test_serving_scheduler_batches(trained, key):
    model, params, pipe = trained
    eng = GenerationEngine(model, params, EngineConfig(
        method="dndm_static", steps=50, nfe_budget=12))
    sched = BatchScheduler(eng, max_batch=4, bucket_len=SEQ)
    ids = [sched.submit(SEQ) for _ in range(10)]
    done = sched.run()
    assert len(done) == 10
    assert all(done[i].result.shape == (SEQ,) for i in ids)
    assert all(done[i].nfe == 12 for i in ids)


@pytest.mark.slow
def test_conditional_translation_learns(key):
    """Conditional path: model learns the cipher and DNDM decodes it."""
    from repro.data.synthetic import bleu
    cfg = ModelConfig(name="mt", arch_type="dense", n_layers=2,
                      d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
                      vocab_size=VOCAB, block_pattern=("attn",) * 2,
                      bidirectional=True)
    model = Model(cfg)
    sch = schedules.linear(50)
    nz = noise.absorbing(VOCAB)
    opt = AdamW(schedule=warmup_cosine(3e-3, 20, 300))
    pipe = DataPipeline(DataConfig(task="translation", vocab=27,
                                   seq_len=24, batch=32))
    trainer = Trainer(model, sch, nz, opt)
    state, hist = trainer.run(iter(pipe), steps=300, verbose=False)

    eng = GenerationEngine(model, state["params"],
                           EngineConfig(method="dndm_topk", steps=50))
    ev = pipe.eval_batches(1)[0]
    cond = {"prefix_tokens": jnp.asarray(ev["src"][:8])}
    out, _ = eng.generate(key, 8, 24, cond=cond)
    score = bleu(np.asarray(out.tokens), ev["x0"][:8])
    acc = (np.asarray(out.tokens) == ev["x0"][:8]).mean()
    assert acc > 0.3, (acc, score)             # far above chance (1/27)
