"""Schedules + transition-time laws: Theorems 3.1, 3.6, D.1."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import forward, noise, schedules, transition


@pytest.mark.parametrize("name", ["linear", "cosine", "cosine_sq"])
@pytest.mark.parametrize("T", [5, 50, 1000])
def test_schedule_monotone(name, T):
    sch = schedules.get(name, T)
    a = sch.alphas
    assert a[0] == 1.0 and a[-1] == 0.0
    assert np.all(np.diff(a) <= 1e-12)
    p = sch.transition_probs()
    assert np.all(p >= 0) and abs(p.sum() - 1) < 1e-9


@given(T=st.integers(2, 200))
@settings(max_examples=20, deadline=None)
def test_schedule_monotone_property(T):
    for name in ("linear", "cosine", "cosine_sq"):
        sch = schedules.get(name, T)
        assert np.all(np.diff(sch.alphas) <= 1e-12)
        assert abs(sch.transition_probs().sum() - 1) < 1e-9


def test_thm_3_6_transition_law(key):
    """Empirical tau frequencies match alpha_{t-1} - alpha_t."""
    T = 20
    sch = schedules.cosine(T)
    dist = transition.from_schedule(sch)
    tau = dist.sample(key, (200_000,))
    counts = np.bincount(np.asarray(tau), minlength=T + 1)[1:]
    emp = counts / counts.sum()
    np.testing.assert_allclose(emp, dist.probs, atol=5e-3)


def test_thm_3_1_marginal_equivalence(key):
    """Non-Markov (eq. 6) and Markov (eq. 1) trajectories share marginals."""
    T, K, n = 10, 8, 30_000
    sch = schedules.linear(T)
    nz = noise.multinomial(K)
    x0 = jnp.zeros((n,), jnp.int32)            # fixed x0 = 0
    k1, k2 = jax.random.split(key)
    traj_nm = np.asarray(forward.non_markov_trajectory(k1, x0, sch, nz))
    traj_m = np.asarray(forward.markov_trajectory(k2, x0, sch, nz))
    for t in (3, 7, 10):
        # P(x_t == x0) must match alpha_t + (1-alpha_t)/K on both
        expect = sch.alphas[t] + (1 - sch.alphas[t]) / K
        for traj in (traj_nm, traj_m):
            frac = (traj[t] == 0).mean()
            assert abs(frac - expect) < 0.01, (t, frac, expect)
        # full marginal histograms agree between the two processes
        h_nm = np.bincount(traj_nm[t], minlength=K) / n
        h_m = np.bincount(traj_m[t], minlength=K) / n
        np.testing.assert_allclose(h_nm, h_m, atol=0.015)


def test_non_markov_single_transition(key):
    """Eq. (7): each token flips at most once along a DNDM trajectory."""
    T, K = 15, 12
    sch = schedules.cosine_sq(T)
    nz = noise.multinomial(K)
    x0 = jax.random.randint(key, (500,), 0, K)
    traj = np.asarray(forward.non_markov_trajectory(
        jax.random.fold_in(key, 1), x0, sch, nz))
    x0n = np.asarray(x0)
    for n in range(traj.shape[1]):
        clean = traj[:, n] == x0n[n]
        # once it leaves x0 it never returns (fixed shared noise w)
        left = np.where(~clean)[0]
        if len(left):
            first = left[0]
            assert np.all(traj[first:, n] == traj[first, n])


def test_thm_d1_expected_nfe(key):
    T, N = 50, 16
    for mk in (lambda: transition.from_schedule(schedules.linear(T)),
               lambda: transition.beta_approx(T, 5.0, 3.0)):
        dist = mk()
        want = dist.expected_nfe(N)
        got = transition.expected_nfe_mc(dist, N, 4000, key)
        assert abs(got - want) / want < 0.03, (dist.name, got, want)
        assert 1 <= want <= min(N, T)


def test_thm_d1_uniform_lower_bound():
    """C >= (1-1/T)^N with equality iff uniform."""
    T, N = 40, 10
    uni = transition.from_schedule(schedules.linear(T))
    c_uni = 1 - uni.expected_nfe(N) / T
    assert abs(c_uni - (1 - 1 / T) ** N) < 1e-9
    beta = transition.beta_approx(T, 8.0, 2.0)
    c_beta = 1 - beta.expected_nfe(N) / T
    assert c_beta >= c_uni - 1e-9


@given(a=st.floats(0.5, 20), b=st.floats(0.5, 20), T=st.integers(5, 100))
@settings(max_examples=15, deadline=None)
def test_beta_approx_valid(a, b, T):
    dist = transition.beta_approx(T, a, b)
    assert abs(dist.probs.sum() - 1) < 1e-9
    assert np.all(dist.probs >= 0)


def test_ordered_transition_times(key):
    dist = transition.from_schedule(schedules.linear(30))
    for order, check in (("l2r", lambda t: np.all(np.diff(t, axis=1) <= 0)),
                         ("r2l", lambda t: np.all(np.diff(t, axis=1) >= 0))):
        tau = np.asarray(transition.sample_transition_times(
            key, dist, 8, 12, order=order))
        assert check(tau), order


def test_nfe_of_counts_unique(key):
    dist = transition.from_schedule(schedules.linear(10))
    tau = jnp.asarray([[1, 1, 2, 9], [3, 3, 3, 3]])
    nfe = np.asarray(transition.nfe_of(tau, 10))
    assert nfe.tolist() == [3, 1]
