"""Posteriors, losses, forward-corruption invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import forward, losses, noise, schedules
from repro.core.posterior import posterior

K = 12


def test_absorbing_posterior_probabilities(key):
    nz = noise.absorbing(K)
    x_t = jnp.asarray([[nz.mask_id, 3]])
    x0p = jax.nn.one_hot(jnp.asarray([[5, 3]]), K)
    p = posterior(x_t, x0p, jnp.asarray([[0.6]]), jnp.asarray([[0.4]]), nz)
    p = np.asarray(p)
    # masked token: stays masked w.p. (1-0.6)/(1-0.4) = 2/3, else reveals 5
    assert abs(p[0, 0, nz.mask_id] - 2 / 3) < 1e-5
    assert abs(p[0, 0, 5] - 1 / 3) < 1e-5
    # clean token: deterministic copy
    assert abs(p[0, 1, 3] - 1.0) < 1e-6
    np.testing.assert_allclose(p.sum(-1), 1.0, atol=1e-5)


def test_multinomial_posterior_normalized(key):
    nz = noise.multinomial(K)
    x_t = jax.random.randint(key, (2, 5), 0, K)
    logits = jax.random.normal(jax.random.fold_in(key, 1), (2, 5, K))
    x0p = jax.nn.softmax(logits, -1)
    p = posterior(x_t, x0p, jnp.full((2, 1), 0.7), jnp.full((2, 1), 0.5), nz)
    np.testing.assert_allclose(np.asarray(p).sum(-1), 1.0, atol=1e-5)
    assert np.all(np.asarray(p) >= 0)


def test_posterior_chain_consistency(key):
    """Ancestral sampling through q(x_{t-1}|x_t,x0) reproduces the
    marginal q(x_{t-1}|x0) (Bayes-rule sanity for the D3PM baseline)."""
    nz = noise.multinomial(K)
    sch = schedules.linear(10)
    t = 6
    n = 40_000
    x0 = jnp.zeros((n,), jnp.int32)
    k1, k2 = jax.random.split(key)
    alphas = jnp.asarray(sch.alphas, jnp.float32)
    x_t = forward.sample_xt(k1, x0, alphas[t], nz)
    x0p = jax.nn.one_hot(jnp.broadcast_to(x0[:, None], (n, 1)), K)
    p = posterior(x_t[:, None], x0p, jnp.full((n, 1), sch.alphas[t - 1],
                  jnp.float32), jnp.full((n, 1), sch.alphas[t],
                  jnp.float32), nz)
    x_tm1 = jax.random.categorical(k2, jnp.log(p + 1e-30), axis=-1)[:, 0]
    frac0 = float((x_tm1 == 0).mean())
    expect = sch.alphas[t - 1] + (1 - sch.alphas[t - 1]) / K
    assert abs(frac0 - expect) < 0.01


@pytest.mark.parametrize("kind", ["absorbing", "multinomial"])
@pytest.mark.parametrize("continuous", [False, True])
def test_reparam_loss_grad_finite(kind, continuous, key):
    sch = schedules.cosine(20)
    nz = noise.get(kind, K)
    x0 = jax.random.randint(key, (4, 8), 0, K - 1)
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, K)) * 0.1

    def apply_fn(params, x_t, t, cond):
        return jax.nn.one_hot(x_t, K) @ params

    def f(w):
        l, m = losses.reparam_ce_loss(key, apply_fn, w, x0, sch, nz,
                                      continuous_time=continuous)
        return l
    l, g = jax.value_and_grad(f)(w)
    assert np.isfinite(float(l)) and np.isfinite(np.asarray(g)).all()


def test_elbo_decreases_for_better_model(key):
    """ELBO loss is lower for a model that predicts x0 well."""
    sch = schedules.linear(20)
    nz = noise.absorbing(K)
    x0 = jax.random.randint(key, (8, 16), 0, K - 1)

    def sharp(params, x_t, t, cond):
        return jax.nn.one_hot(x0, K) * params

    l_good, _ = losses.elbo_loss(key, sharp, 8.0, x0, sch, nz)
    l_flat, _ = losses.elbo_loss(key, sharp, 0.0, x0, sch, nz)
    assert float(l_good) < float(l_flat)


@given(st.integers(0, 10_000), st.integers(2, 30))
@settings(max_examples=10, deadline=None)
def test_corruption_marginal_property(seed, T):
    """x_t == x0 frequency ~ alpha_t + (1-alpha_t)/K for multinomial."""
    key = jax.random.PRNGKey(seed)
    sch = schedules.linear(T)
    nz = noise.multinomial(K)
    x0 = jnp.zeros((5000,), jnp.int32)
    t = jnp.full((5000,), T // 2 + 1)
    x_t, _, alpha = forward.corrupt_for_training(key, x0, sch, nz, t=t)
    frac = float((x_t == 0).mean())
    expect = float(alpha[0] + (1 - alpha[0]) / K)
    assert abs(frac - expect) < 0.04


def test_checkpoint_roundtrip(tmp_path, key):
    from repro.training import checkpoint
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": [jnp.zeros((2,)), jnp.full((1,), 7.0)]}}
    checkpoint.save(str(tmp_path / "ck"), tree)
    back = checkpoint.load(str(tmp_path / "ck"))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_adamw_reduces_quadratic():
    from repro.training.optim import AdamW, constant
    opt = AdamW(schedule=constant(0.1), weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, m = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.05
