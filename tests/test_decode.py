"""Decode layer: fused_update backend parity (Pallas interpret vs pure-JAX
reference, plus compiled Pallas on TPU), argmax and Gumbel-sample modes,
aligned and padded shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import decode, noise
from repro.core.samplers import SamplerConfig

# compiled Mosaic only exists on TPU; CPU CI compares interpret vs reference
BACKENDS = ["reference", "interpret"] + (
    ["pallas"] if jax.default_backend() == "tpu" else [])

# (2, 16, 128): block-aligned.  (1, 13, 100): N and K both need padding;
# with block_n=8 / block_v=64 the grid is multi-block in both dimensions.
SHAPES = [(2, 16, 128), (1, 13, 100)]


@pytest.mark.parametrize("B,N,K", SHAPES)
@pytest.mark.parametrize("version", [1, 2])
@pytest.mark.parametrize("mode", ["argmax", "sample"])
def test_fused_update_backend_parity(B, N, K, version, mode, key):
    ks = jax.random.split(key, 4)
    logits = jax.random.normal(ks[0], (B, N, K))
    x = jax.random.randint(ks[1], (B, N), 0, K)
    tau = jax.random.randint(ks[2], (B, N), 1, 8)
    nz = noise.absorbing(K)
    cfg = SamplerConfig(x0_mode=mode, temperature=0.7)
    for t in (1, 4, 7):
        outs = [
            np.asarray(decode.fused_update(
                ks[3], logits, x, tau, t, nz, cfg, version=version,
                backend=b, block_n=8, block_v=64))
            for b in BACKENDS
        ]
        for b, o in zip(BACKENDS[1:], outs[1:]):
            assert (o == outs[0]).all(), (b, t)


def test_fused_update_bf16_and_multinomial(key):
    """bf16 logits and a mask-free noise dist go through every backend."""
    B, N, K = 2, 24, 96
    ks = jax.random.split(key, 4)
    logits = jax.random.normal(ks[0], (B, N, K), jnp.bfloat16)
    x = jax.random.randint(ks[1], (B, N), 0, K)
    tau = jax.random.randint(ks[2], (B, N), 1, 6)
    nz = noise.multinomial(K)
    cfg = SamplerConfig(x0_mode="argmax")
    outs = [np.asarray(decode.fused_update(ks[3], logits, x, tau, 3, nz,
                                           cfg, backend=b))
            for b in BACKENDS]
    for o in outs[1:]:
        assert (o == outs[0]).all()


def test_fused_update_matches_decode_tokens(key):
    """With tau == t everywhere, fused_update returns exactly the decoded
    x0_hat — the same tokens decode_tokens picks (shared decode math)."""
    B, N, K = 2, 16, 64
    ks = jax.random.split(key, 2)
    logits = jax.random.normal(ks[0], (B, N, K))
    x = jnp.zeros((B, N), jnp.int32)
    tau = jnp.full((B, N), 5, jnp.int32)
    nz = noise.absorbing(K)
    for mode in ("argmax", "sample"):
        cfg = SamplerConfig(x0_mode=mode)
        for backend in BACKENDS:
            fused = decode.fused_update(ks[1], logits, x, tau, 5, nz, cfg,
                                        backend=backend)
            tok, score = decode.decode_tokens(ks[1], logits, nz, cfg)
            assert (np.asarray(fused) == np.asarray(tok)).all(), (mode,
                                                                  backend)
        assert np.isfinite(np.asarray(score)).all()
        # the absorbing [MASK] id must never be decoded as a clean token
        assert not (np.asarray(tok) == nz.mask_id).any()


@pytest.mark.parametrize("B,N,K", SHAPES)
@pytest.mark.parametrize("mode", ["argmax", "sample"])
@pytest.mark.parametrize("noise_kind", ["absorbing", "multinomial"])
def test_decode_tokens_backend_parity(B, N, K, mode, noise_kind, key):
    """(token, score) parity across backends: tokens bitwise, scores
    allclose (online vs direct logsumexp), padded shapes included."""
    ks = jax.random.split(key, 2)
    logits = jax.random.normal(ks[0], (B, N, K))
    nz = noise.get(noise_kind, K)
    cfg = SamplerConfig(x0_mode=mode, temperature=0.7)
    ref_tok, ref_score = decode.decode_tokens(ks[1], logits, nz, cfg,
                                              backend="reference")
    for b in BACKENDS[1:]:
        tok, score = decode.decode_tokens(ks[1], logits, nz, cfg,
                                          backend=b, block_n=8, block_v=64)
        assert (np.asarray(tok) == np.asarray(ref_tok)).all(), (b, mode)
        np.testing.assert_allclose(np.asarray(score), np.asarray(ref_score),
                                   atol=2e-5, rtol=2e-5)


def test_decode_tokens_agrees_with_fused_update_all_backends(key):
    """The (token) half of decode_tokens is the same selection fused_update
    applies — bitwise, across every backend pairing."""
    B, N, K = 2, 13, 100                      # padded in both dims
    ks = jax.random.split(key, 2)
    logits = jax.random.normal(ks[0], (B, N, K))
    x = jnp.zeros((B, N), jnp.int32)
    tau = jnp.full((B, N), 3, jnp.int32)
    nz = noise.absorbing(K)
    for mode in ("argmax", "sample"):
        cfg = SamplerConfig(x0_mode=mode)
        for bf in BACKENDS:
            fused = decode.fused_update(ks[1], logits, x, tau, 3, nz, cfg,
                                        backend=bf, block_n=8, block_v=64)
            for bd in BACKENDS:
                tok, _ = decode.decode_tokens(ks[1], logits, nz, cfg,
                                              backend=bd, block_n=8,
                                              block_v=64)
                assert (np.asarray(fused) == np.asarray(tok)).all(), (bf, bd)


def test_decode_tokens_env_override(monkeypatch, key):
    """REPRO_DECODE_BACKEND steers decode_tokens exactly like fused_update."""
    B, N, K = 1, 8, 32
    ks = jax.random.split(key, 2)
    logits = jax.random.normal(ks[0], (B, N, K))
    nz = noise.absorbing(K)
    cfg = SamplerConfig(x0_mode="sample")
    ref_tok, ref_score = decode.decode_tokens(ks[1], logits, nz, cfg,
                                              backend="reference")
    monkeypatch.setenv("REPRO_DECODE_BACKEND", "interpret")
    tok, score = decode.decode_tokens(ks[1], logits, nz, cfg)  # auto
    assert (np.asarray(tok) == np.asarray(ref_tok)).all()
    np.testing.assert_allclose(np.asarray(score), np.asarray(ref_score),
                               atol=2e-5, rtol=2e-5)


def test_decode_tokens_scores_are_chosen_logprob(key):
    """Scores == log-softmax of the chosen token (the top-k rank key)."""
    B, N, K = 2, 8, 32
    ks = jax.random.split(key, 2)
    logits = jax.random.normal(ks[0], (B, N, K))
    nz = noise.multinomial(K)
    cfg = SamplerConfig(x0_mode="argmax", temperature=0.5)
    tok, score = decode.decode_tokens(ks[1], logits, nz, cfg)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32) / 0.5, axis=-1)
    want = np.take_along_axis(np.asarray(logp),
                              np.asarray(tok)[..., None], -1)[..., 0]
    np.testing.assert_allclose(np.asarray(score), want, atol=1e-6)


def test_backend_resolution(monkeypatch):
    assert decode.resolve_backend("reference") == "reference"
    assert decode.resolve_backend("auto") in decode.BACKENDS
    monkeypatch.setenv("REPRO_DECODE_BACKEND", "interpret")
    assert decode.default_backend() == "interpret"
    monkeypatch.setenv("REPRO_DECODE_BACKEND", "nope")
    with pytest.raises(ValueError):
        decode.default_backend()
    with pytest.raises(ValueError):
        decode.resolve_backend("nope")
