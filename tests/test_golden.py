"""Golden-trajectory regression fixtures (ISSUE 8).

Small fixed-seed generations for one sampler per family — dndm (host
loop), dndm_topk (confidence-ranked reveal), rdm (scan baseline), ddim
(multinomial subsequence baseline) — are checked into
``tests/golden/trajectories.json`` together with their NFE and (for
plan-capable methods) the predetermined call schedule.  Replaying them
pins the whole decode path: a sampler refactor that silently changes
tokens, NFE accounting, or the tau sampling fails here first.

The fixtures are recorded on the CPU reference decode backend under the
pinned CI jax version; regenerate intentionally with::

    REPRO_REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_golden.py

(the test then rewrites the fixture and passes — diff it in review).
"""
import json
import os
import pathlib

import jax
import numpy as np
import pytest

from repro.core import decode as decode_lib
from repro.models import Model, ModelConfig
from repro.serving import EngineConfig, GenerationEngine

VOCAB, SEQ, STEPS, BATCH = 12, 8, 6, 2
GOLDEN = pathlib.Path(__file__).parent / "golden" / "trajectories.json"
REGEN = os.environ.get("REPRO_REGEN_GOLDEN", "") == "1"

# (method, noise_kind, engine knobs) — one per sampler family
CASES = [
    ("dndm", "absorbing", {}),
    ("dndm_topk", "absorbing", {}),
    ("rdm", "absorbing", {}),
    ("ddim", "multinomial", {"ddim_stride": 2}),
]

pytestmark = pytest.mark.skipif(
    decode_lib.default_backend() != "reference",
    reason="golden fixtures are recorded on the reference decode backend")


@pytest.fixture(scope="module")
def engines():
    cfg = ModelConfig(name="golden", arch_type="dense", n_layers=1,
                      d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                      vocab_size=VOCAB, block_pattern=("attn",),
                      bidirectional=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    out = {}
    for _, kind, _ in CASES:
        if kind not in out:
            out[kind] = GenerationEngine(model, params, EngineConfig(
                method="dndm" if kind == "absorbing" else "ddim",
                steps=STEPS, noise_kind=kind, shared_tau=False,
                ddim_stride=2))
    return out


def _generate(engines):
    rec = {"jax": jax.__version__,
           "config": {"vocab": VOCAB, "seq": SEQ, "steps": STEPS,
                      "batch": BATCH},
           "trajectories": {}}
    for method, kind, _ in CASES:
        eng = engines[kind]
        key = jax.random.PRNGKey(42)
        out, _ = eng.generate(key, BATCH, SEQ, method=method)
        entry = {"tokens": np.asarray(out.tokens).tolist(),
                 "nfe": int(out.nfe)}
        if eng.check_method(method).schedule_fn is not None:
            plan = eng.plan_request(key, SEQ, method)
            entry["call_times"] = np.asarray(plan.times).tolist()
        rec["trajectories"][method] = entry
    return rec


def test_golden_trajectories(engines):
    got = _generate(engines)
    if REGEN or not GOLDEN.exists():
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN.write_text(json.dumps(got, indent=1, sort_keys=True) + "\n")
        if not REGEN:
            pytest.skip("golden fixture recorded; re-run to compare")
        return
    want = json.loads(GOLDEN.read_text())
    if want["jax"] != jax.__version__:
        pytest.skip(f"fixture recorded under jax {want['jax']}, running "
                    f"{jax.__version__} — REPRO_REGEN_GOLDEN=1 to re-pin")
    assert got["config"] == want["config"]
    for method, entry in want["trajectories"].items():
        g = got["trajectories"][method]
        assert g["nfe"] == entry["nfe"], method
        assert g["tokens"] == entry["tokens"], (
            f"{method}: tokens drifted from the golden fixture — if the "
            "change is intentional, REPRO_REGEN_GOLDEN=1 and review the "
            "diff")
        if "call_times" in entry:
            assert g["call_times"] == entry["call_times"], method


def test_golden_covers_every_family():
    """The fixture must keep one method per sampler family (host DNDM,
    ranked reveal, scan baseline, multinomial baseline)."""
    if not GOLDEN.exists():
        pytest.skip("fixture not recorded yet")
    want = json.loads(GOLDEN.read_text())
    assert set(want["trajectories"]) == {m for m, _, _ in CASES}
