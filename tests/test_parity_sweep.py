"""Differential decode-backend parity sweep (ISSUE 8).

Every registry method runs the same tiny generation under the pure-jnp
``reference`` decode backend and the pallas ``interpret`` backend and
must produce identical tokens — closing the gap where only
dndm_update/decode_scores had pairwise parity tests while full sampler
trajectories did not.

The decode backend is resolved at trace time, so the sweep clears every
jit cache and builds fresh engines per backend; a mismatch here means
the fused kernel path and the reference path disagree somewhere a unit
parity test does not reach (e.g. the revealed-carry interaction, the
static-grid bucketization, or the scan wrappers).
"""
import os

import jax
import numpy as np
import pytest

from repro.core.samplers import registry
from repro.models import Model, ModelConfig
from repro.serving import EngineConfig, GenerationEngine

VOCAB, SEQ, STEPS, BATCH = 12, 8, 4, 2
BACKENDS = ("reference", "interpret")

# every registry method, under one compatible noise kind each
SWEEP = [(m, "absorbing") for m in registry.names("absorbing")] + \
        [(m, "multinomial") for m in registry.names("multinomial")
         if m not in registry.names("absorbing")]


@pytest.fixture(scope="module")
def sweep_tokens():
    """{(backend, method): tokens} for the full registry, computed once
    per backend behind a jit-cache flush."""
    cfg = ModelConfig(name="sweep", arch_type="dense", n_layers=1,
                      d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                      vocab_size=VOCAB, block_pattern=("attn",),
                      bidirectional=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    saved = os.environ.get("REPRO_DECODE_BACKEND")
    results = {}
    try:
        for backend in BACKENDS:
            os.environ["REPRO_DECODE_BACKEND"] = backend
            jax.clear_caches()      # backend is baked in at trace time
            engines = {
                kind: GenerationEngine(model, params, EngineConfig(
                    method="dndm" if kind == "absorbing" else "ddim",
                    steps=STEPS, noise_kind=kind, nfe_budget=2,
                    ddim_stride=2, shared_tau=False))
                for kind in {k for _, k in SWEEP}}
            for method, kind in SWEEP:
                out, _ = engines[kind].generate(
                    jax.random.PRNGKey(7), BATCH, SEQ, method=method)
                results[(backend, method)] = np.asarray(out.tokens)
    finally:
        if saved is None:
            os.environ.pop("REPRO_DECODE_BACKEND", None)
        else:
            os.environ["REPRO_DECODE_BACKEND"] = saved
        jax.clear_caches()
    return results


def test_sweep_covers_whole_registry():
    assert {m for m, _ in SWEEP} == set(registry.names())


@pytest.mark.parametrize("method,kind", SWEEP)
def test_backend_parity(sweep_tokens, method, kind):
    ref = sweep_tokens[("reference", method)]
    interp = sweep_tokens[("interpret", method)]
    assert ref.shape == (BATCH, SEQ)
    assert (0 <= ref).all() and (ref < VOCAB).all()
    np.testing.assert_array_equal(
        ref, interp,
        err_msg=f"{method} ({kind}): reference vs interpret tokens differ")
