"""Registry smoke: every registered method generates end-to-end through
GenerationEngine, reports a sane NFE, and the engine serves reconfigured
knobs and per-request method overrides without stale compiled samplers."""
import jax
import numpy as np
import pytest

from repro.core.samplers import registry
from repro.models import Model, ModelConfig
from repro.serving import BatchScheduler, EngineConfig, GenerationEngine

VOCAB, SEQ, STEPS = 12, 8, 8


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="reg", arch_type="dense", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=VOCAB,
                      block_pattern=("attn",), bidirectional=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _engine(tiny, method, **kw):
    model, params = tiny
    spec = registry.get(method)
    nk = ("absorbing" if "absorbing" in spec.noise_kinds
          else "multinomial")
    defaults = dict(method=method, steps=STEPS, nfe_budget=4, noise_kind=nk)
    defaults.update(kw)
    return GenerationEngine(model, params, EngineConfig(**defaults))


@pytest.mark.parametrize("method", registry.names())
def test_every_method_generates(tiny, method, key):
    eng = _engine(tiny, method)
    out, wall = eng.generate(key, 2, SEQ)
    toks = np.asarray(out.tokens)
    assert toks.shape == (2, SEQ)
    assert toks.dtype == np.int32
    assert (0 <= toks).all() and (toks < VOCAB).all()
    assert 0 < out.nfe <= max(STEPS, SEQ)
    spec = registry.get(method)
    if spec.kind == "scan":
        assert out.nfe == spec.static_nfe(eng.runtime(), SEQ)


def test_engine_rejects_unknown_method(tiny):
    model, params = tiny
    with pytest.raises(KeyError, match="available"):
        GenerationEngine(model, params, EngineConfig(method="nope"))


def test_engine_rejects_incompatible_noise(tiny, key):
    model, params = tiny
    # at construction for the configured method...
    with pytest.raises(ValueError, match="noise"):
        GenerationEngine(model, params, EngineConfig(
            method="mask_predict", steps=STEPS, noise_kind="multinomial"))
    # ...and at generate() for per-call overrides
    eng = _engine(tiny, "rdm")
    with pytest.raises(ValueError, match="noise"):
        eng.generate(key, 2, SEQ, method="ddim")


def test_jit_cache_tracks_reconfigured_knobs(tiny, key):
    """Reconfiguring nfe_budget/order/shared_tau must not serve a stale
    compiled sampler (the cache key covers every traced knob)."""
    eng = _engine(tiny, "dndm_static")
    out, _ = eng.generate(key, 2, SEQ)
    assert out.nfe == 4
    eng.cfg.nfe_budget = 6
    out, _ = eng.generate(key, 2, SEQ)
    assert out.nfe == 6
    eng.cfg.order = "l2r"
    eng.cfg.shared_tau = False
    out, _ = eng.generate(key, 2, SEQ)
    assert out.nfe == 6                          # still the new budget


def test_reconfigured_steps_rebuild_schedule(tiny, key):
    """Mutating steps must rebuild the schedule/transition laws, not just
    retrace with the old ones frozen at construction."""
    eng = _engine(tiny, "d3pm")
    out, _ = eng.generate(key, 2, SEQ)
    assert out.nfe == STEPS
    eng.cfg.steps = STEPS * 2
    out, _ = eng.generate(key, 2, SEQ)
    assert out.nfe == STEPS * 2
    assert eng.runtime().schedule.T == STEPS * 2


def test_generate_method_override_and_scheduler_grouping(tiny, key):
    """One engine serves every method; the scheduler batches per method."""
    eng = _engine(tiny, "dndm_static")
    out, _ = eng.generate(key, 2, SEQ, method="rdm")
    assert out.nfe == STEPS

    sched = BatchScheduler(eng, max_batch=4, bucket_len=SEQ)
    default_ids = [sched.submit(SEQ) for _ in range(3)]
    rdm_ids = [sched.submit(SEQ, method="rdm") for _ in range(2)]
    with pytest.raises(KeyError):
        sched.submit(SEQ, method="not_a_method")
    with pytest.raises(ValueError, match="noise"):
        sched.submit(SEQ, method="ddim")     # multinomial-only sampler
    done = sched.run()
    assert len(done) == 5
    assert all(done[i].nfe == 4 for i in default_ids)
    assert all(done[i].nfe == STEPS for i in rdm_ids)
    assert all(done[i].result.shape == (SEQ,) for i in done)


def test_describe_lists_every_method():
    sheet = registry.describe()
    for name in registry.names():
        assert name in sheet
    assert "nfe_budget" in registry.describe("dndm_static")


def test_registry_rejects_bad_specs():
    with pytest.raises(KeyError, match="available"):
        registry.get("definitely_not_registered")
    with pytest.raises(ValueError, match="already registered"):
        registry.register(registry.get("dndm"))
    with pytest.raises(ValueError, match="static_nfe"):
        registry.register(registry.SamplerSpec(
            "broken", "scan", lambda *a: None))
