"""Telemetry layer: metric semantics, span nesting/export schema,
per-step reveal-count correctness, the disabled-path overhead guard, and
the engine/scheduler integration (host warm-up split, amortized wall)."""
import time

import jax
import numpy as np
import pytest

from repro import obs
from repro.core.samplers import loop
from repro.obs import schema
from repro.models import Model, ModelConfig
from repro.serving import BatchScheduler, EngineConfig, GenerationEngine

VOCAB, SEQ, STEPS = 12, 8, 4


@pytest.fixture()
def telemetry():
    """Enable obs for one test; always restore the disabled default."""
    obs.metrics.reset()
    obs.tracing.clear()
    obs.enable()
    yield
    obs.metrics.reset()
    obs.tracing.clear()
    obs.disable()


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="obs", arch_type="dense", n_layers=1,
                      d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                      vocab_size=VOCAB, block_pattern=("attn",),
                      bidirectional=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _engine(tiny, method="dndm"):
    model, params = tiny
    return GenerationEngine(model, params, EngineConfig(
        method=method, steps=STEPS, nfe_budget=2))


# ------------------------------------------------------------------
# metrics registry
# ------------------------------------------------------------------

def test_counter_gauge_histogram_semantics(telemetry):
    c = obs.counter("t.count", "help text")
    c.inc(a="x")
    c.inc(2, a="x")
    c.inc(5, a="y")
    assert c.value(a="x") == 3
    assert c.value(a="y") == 5
    assert c.value(a="unseen") == 0

    g = obs.gauge("t.gauge")
    g.set(1.5, k="v")
    g.set(2.5, k="v")                       # overwrites
    assert g.value(k="v") == 2.5

    h = obs.histogram("t.hist")
    for v in (0.1, 0.2, 0.3):
        h.observe(v, op="f")
    s = h.value(op="f")
    assert s["count"] == 3
    assert s["min"] == pytest.approx(0.1)
    assert s["max"] == pytest.approx(0.3)
    assert s["sum"] == pytest.approx(0.6)

    snap = obs.snapshot()
    assert snap["t.count"]["type"] == "counter"
    assert snap["t.count"]["help"] == "help text"
    series = {tuple(s["labels"].items()): s["value"]
              for s in snap["t.count"]["series"]}
    assert series[(("a", "x"),)] == 3
    assert snap["t.hist"]["series"][0]["value"]["mean"] == pytest.approx(0.2)
    # same name, different type -> error
    with pytest.raises(TypeError):
        obs.gauge("t.count")


def test_reset_clears_values_not_instruments(telemetry):
    c = obs.counter("t.reset")
    c.inc(7)
    obs.metrics.reset()
    assert c.value() == 0
    assert obs.counter("t.reset") is c


# ------------------------------------------------------------------
# tracing
# ------------------------------------------------------------------

def test_span_nesting_and_export_schema(telemetry, tmp_path):
    path = tmp_path / "trace.jsonl"
    obs.set_sink(str(path))
    with obs.span("outer", method="dndm") as sp:
        obs.event("tick", i=0, t=np.int32(3))   # numpy scalar coerced
        with obs.span("inner"):
            pass
        sp.set(nfe=4)
    obs.write_metrics_record()
    obs.tracing.close_sink()

    recs = schema.validate_trace_lines(path.read_text().splitlines())
    by_name = {r.get("name"): r for r in recs}
    outer, inner, tick = by_name["outer"], by_name["inner"], by_name["tick"]
    # children point at the enclosing span; the root has no parent
    assert outer["parent_id"] is None
    assert inner["parent_id"] == outer["span_id"]
    assert tick["parent_id"] == outer["span_id"]
    assert tick["attrs"] == {"i": 0, "t": 3}
    # attrs set mid-span are exported; spans carry durations
    assert outer["attrs"] == {"method": "dndm", "nfe": 4}
    assert outer["dur_s"] >= inner["dur_s"] >= 0.0
    assert recs[-1]["kind"] == "metrics"


def test_null_span_when_disabled():
    assert not obs.enabled()
    sp = obs.span("nope", a=1)
    assert sp is obs.tracing.NULL_SPAN
    with sp as s:
        s.set(b=2)                              # no-op, no error
    obs.event("nope")
    assert obs.tracing.records() == []


# ------------------------------------------------------------------
# per-step reveal counts (|R_t|)
# ------------------------------------------------------------------

def test_reveal_series_hand_computed():
    # tau = [3, 1, 3, 2]; unique descending times = [3, 2, 1]
    tau = np.array([[3, 1, 3, 2]])
    times = np.array([3, 2, 1])
    # Algorithm 1 reveals #(tau == t) per step
    assert loop.reveal_series(tau, times, version=1).tolist() == [2, 1, 1]
    # Algorithm 3 re-updates everything already revealed (tau >= t)
    assert loop.reveal_series(tau, times, version=2).tolist() == [2, 3, 4]
    # batch mean: second row reveals all 4 tokens at t=3
    tau2 = np.array([[3, 1, 3, 2], [3, 3, 3, 3]])
    assert loop.reveal_series(tau2, times, version=1).tolist() == [3, 0.5, 0.5]


def test_dndm_generate_records_reveal_series(telemetry, tiny, key):
    eng = _engine(tiny, "dndm")
    out, _ = eng.generate(key, 2, SEQ)
    reveals = out.aux["reveal_counts"]
    # every token is revealed exactly once across the walk
    assert float(np.sum(reveals)) == pytest.approx(SEQ)
    # the series matches a hand recomputation from the returned tau set
    tau = np.asarray(jax.device_get(out.aux["tau"]))
    expect = loop.reveal_series(tau, out.aux["times"], version=1)
    np.testing.assert_allclose(reveals, expect)
    # ... and is exported per step as sampler.step events under the
    # engine.generate span
    recs = obs.tracing.records()
    gen = [r for r in recs if r["kind"] == "span"
           and r["name"] == "engine.generate"]
    steps = [r for r in recs if r["kind"] == "event"
             and r["name"] == "sampler.step"]
    assert gen and gen[0]["attrs"]["nfe"] == out.nfe
    assert gen[0]["attrs"]["cache"] == "miss"
    assert gen[0]["attrs"]["backend"] in ("pallas", "interpret", "reference")
    step_reveals = [r["attrs"]["reveal"] for r in steps]
    # the untimed jit warm-up run is obs-suppressed, so the series shows
    # up exactly once — not doubled by the cache-miss warm-up replay
    assert step_reveals == list(map(float, expect))


# ------------------------------------------------------------------
# engine: jit-cache counters + host warm-up split
# ------------------------------------------------------------------

def test_host_warmup_split(telemetry, tiny, key):
    """First host-sampler call per key warms the per-step jit caches
    untimed; wall_seconds is steady-state and the warm-up surplus is
    reported as compile_seconds (0.0 once warm)."""
    eng = _engine(tiny, "dndm")
    out, wall = eng.generate(key, 2, SEQ)
    assert out.aux["compile_seconds"] >= 0.0
    assert obs.counter("engine.jit_cache.misses").value(
        method="dndm", kind="host") == 1
    out2, wall2 = eng.generate(key, 2, SEQ)
    assert out2.aux["compile_seconds"] == 0.0
    assert obs.counter("engine.jit_cache.hits").value(
        method="dndm", kind="host") == 1
    # warm-up reruns the same PRNG key: outputs identical
    assert (np.asarray(out.tokens) == np.asarray(out2.tokens)).all()
    assert wall >= 0 and wall2 >= 0


def test_suppressed_silences_without_flipping_global(telemetry):
    """obs.suppressed(): instruments and events are silenced inside the
    context (enabled() reads False), the global on-state is untouched,
    and nesting unwinds correctly."""
    c = obs.counter("suppress.probe")
    c.inc()
    with obs.suppressed():
        assert not obs.enabled()
        c.inc()
        obs.event("suppress.nope")
        with obs.suppressed():
            c.inc()
        c.inc()                     # still inside the outer context
    assert obs.enabled()
    c.inc()
    assert c.value() == 2
    assert all(r["name"] != "suppress.nope"
               for r in obs.tracing.records())


def test_cold_warm_metric_equality(telemetry, tiny, key):
    """Regression (cold-key double counting): a jit-cache-miss host call
    runs the sampler twice (untimed warm-up + timed run) but must record
    each per-step metric exactly once — the same counts a warm call
    records.  Pre-fix, every cold call double-counted sampler.step
    events, step/reveal histograms and decode.* counters."""
    eng = _engine(tiny, "dndm")

    def emission_counts():
        h_step = obs.histogram("sampler.step_seconds").value(loop="host")
        h_rev = obs.histogram("sampler.reveal_count").value(
            sampler="dndm", version=1)
        steps = sum(1 for r in obs.tracing.records()
                    if r["kind"] == "event" and r["name"] == "sampler.step")
        return ((h_step or {"count": 0})["count"],
                (h_rev or {"count": 0})["count"], steps)

    out, _ = eng.generate(key, 2, SEQ)          # cold: warm-up + timed
    cold = emission_counts()
    obs.metrics.reset()
    obs.tracing.clear()
    out2, _ = eng.generate(key, 2, SEQ)         # warm: timed run only
    warm = emission_counts()
    assert cold == warm
    assert cold[0] == out.nfe                   # one step record per call
    assert (np.asarray(out.tokens) == np.asarray(out2.tokens)).all()


def test_scan_cache_counters(telemetry, tiny, key):
    eng = _engine(tiny, "dndm_static")
    eng.generate(key, 2, SEQ)
    eng.generate(key, 2, SEQ)
    assert obs.counter("engine.jit_cache.misses").value(
        method="dndm_static", kind="scan") == 1
    assert obs.counter("engine.jit_cache.hits").value(
        method="dndm_static", kind="scan") == 1
    assert obs.counter("engine.nfe").value(method="dndm_static") == 4


# ------------------------------------------------------------------
# scheduler: amortized wall + occupancy metrics
# ------------------------------------------------------------------

def test_scheduler_amortized_wall_and_occupancy(telemetry, tiny):
    eng = _engine(tiny, "dndm_static")
    sched = BatchScheduler(eng, max_batch=4, bucket_len=SEQ)
    rids = [sched.submit(SEQ) for _ in range(3)]
    done = sched.run()
    for rid in rids:
        r = done[rid]
        assert r.batch_size == 3
        assert r.batch_wall > 0
        assert r.wall == pytest.approx(r.batch_wall / 3)
    occ = obs.histogram("scheduler.occupancy").value(method="dndm_static")
    assert occ["count"] == 1
    assert occ["max"] == pytest.approx(3 / 4)   # 3 requests in a 4-bucket
    assert obs.counter("scheduler.padded_rows").value(
        method="dndm_static") == 1
    # the exported batch span carries the post-run attrs (wall/occupancy)
    batch_spans = [r for r in obs.tracing.records()
                   if r["kind"] == "span" and r["name"] == "scheduler.batch"]
    assert batch_spans and {"wall_s", "occupancy", "padded_rows"} <= \
        set(batch_spans[0]["attrs"])
    # nesting: the engine span is a child of the scheduler batch span
    gen = [r for r in obs.tracing.records()
           if r["kind"] == "span" and r["name"] == "engine.generate"]
    assert gen[0]["parent_id"] == batch_spans[0]["span_id"]


# ------------------------------------------------------------------
# disabled-path overhead guard
# ------------------------------------------------------------------

def test_disabled_path_overhead():
    """With telemetry off, an instrumented call site costs one guard
    check — no allocation, no records.  Budget: well under the <2%
    engine.generate regression ceiling (a host step is >=100us of real
    work; we require the full span+event+counter trio to stay under
    10us/op even on a loaded CI machine)."""
    assert not obs.enabled()
    c = obs.counter("t.overhead")
    n = 20_000
    t0 = time.perf_counter()
    for _ in range(n):
        obs.span("x", a=1)
        obs.event("y", b=2)
        c.inc(3, d="z")
    per_op = (time.perf_counter() - t0) / n
    assert per_op < 10e-6, f"disabled telemetry costs {per_op * 1e6:.2f}us"
    assert obs.tracing.records() == []
    assert c.value(d="z") == 0
    assert obs.snapshot() == {}


# ------------------------------------------------------------------
# schema validator
# ------------------------------------------------------------------

def test_schema_rejects_malformed_trace():
    with pytest.raises(schema.SchemaError):
        schema.validate_trace_lines(['{"kind": "span", "name": "x"}'])
    with pytest.raises(schema.SchemaError):
        schema.validate_trace_lines(["not json"])
    # a valid line passes
    ok = ('{"kind": "event", "name": "e", "ts": 1.0, "span_id": 1, '
          '"parent_id": null, "attrs": {}}')
    assert len(schema.validate_trace_lines([ok])) == 1


def test_schema_rejects_malformed_bench():
    with pytest.raises(schema.SchemaError):
        schema.validate_bench({"schema": 1})
    good = {
        "schema": 2, "jax_backend": "cpu", "quick": True,
        "config": {"batch": 8, "seq": 32, "steps": 16},
        "methods": {"dndm": {
            "noise": "absorbing", "kind": "host", "wall_seconds": 0.1,
            "compile_seconds": 0.0, "nfe": 10, "tokens_per_second": 100.0,
            "us_per_nfe": 9.0,
            "metrics": {"jit_cache_hits": 1, "jit_cache_misses": 1}}},
        "telemetry": {"enabled": True, "trace": None, "metrics": {}},
    }
    schema.validate_bench(good)                  # no raise
    bad = {**good, "methods": {}}
    with pytest.raises(schema.SchemaError):
        schema.validate_bench(bad)


# ------------------------------------------------------------------
# ISSUE 10: quantile sketches behind every histogram
# ------------------------------------------------------------------
import json
import threading
import urllib.request

from repro.obs import exporter, regress, slo
from repro.obs.sketch import DDSketch, quantile_of_snapshot


def test_sketch_relative_error_and_merge_exactness():
    """Deterministic companion to the hypothesis properties: quantile
    estimates stay within alpha relative error across five decades, and
    merging per-shard sketches reproduces the global sketch exactly."""
    vals = [10.0 ** (i / 100.0) for i in range(-200, 301)]  # 1e-2..1e3
    sk = DDSketch(alpha=0.01)
    for v in vals:
        sk.add(v)
    srt = sorted(vals)
    for q in (0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0):
        true = srt[int(q * (len(vals) - 1))]
        assert abs(sk.quantile(q) - true) <= 0.01 * true + 1e-12, q

    a, b = DDSketch(), DDSketch()
    for v in vals[::2]:
        a.add(v)
    for v in vals[1::2]:
        b.add(v)
    merged = a.copy().merge(b)
    assert merged.count == sk.count
    assert merged.bins == sk.bins
    # round-trip through the snapshot JSON form
    back = DDSketch.from_dict(json.loads(json.dumps(merged.to_dict())))
    assert back.quantile(0.95) == merged.quantile(0.95)


def test_sketch_fixed_memory_collapse_keeps_upper_quantiles():
    """max_bins is a hard bound; collapsing the low tail must not move
    p95/p99 (they live in the highest buckets)."""
    sk = DDSketch(alpha=0.01, max_bins=64)
    vals = [10.0 ** (i / 50.0) for i in range(-300, 301)]   # 1e-6..1e6
    for v in vals:
        sk.add(v)
    assert len(sk.bins) <= 64
    srt = sorted(vals)
    for q in (0.95, 0.99):
        true = srt[int(q * (len(vals) - 1))]
        assert abs(sk.quantile(q) - true) <= 0.01 * true


def test_sketch_zero_bucket_and_validation():
    sk = DDSketch()
    assert sk.quantile(0.5) == 0.0                  # empty
    sk.add(0.0, n=3)
    sk.add(-1.0)
    sk.add(5.0)
    assert sk.count == 5
    assert sk.quantile(0.0) == 0.0                  # zeros rank first
    assert abs(sk.quantile(1.0) - 5.0) <= 0.05
    with pytest.raises(ValueError):
        sk.quantile(1.5)
    with pytest.raises(ValueError):
        DDSketch(alpha=0.0)
    with pytest.raises(ValueError):
        DDSketch().merge(DDSketch(alpha=0.05))


def test_histogram_snapshot_carries_sketch_quantiles(telemetry):
    """Every histogram series snapshot now carries p50/p95/p99 plus the
    serialized sketch, and quantile_of_snapshot recomputes any quantile
    from the artifact alone (no live registry needed)."""
    h = obs.histogram("t.sketch_hist")
    vals = [0.001 * (i + 1) for i in range(500)]
    for v in vals:
        h.observe(v, op="f")
    snap = obs.snapshot()
    sv = snap["t.sketch_hist"]["series"][0]["value"]
    for q, field in ((0.5, "p50"), (0.95, "p95"), (0.99, "p99")):
        true = sorted(vals)[int(q * (len(vals) - 1))]
        assert abs(sv[field] - true) <= 0.01 * true + 1e-9
        assert sv[field] == quantile_of_snapshot(sv, q)
    # schema: the new fields are required, not incidental
    schema.validate_metrics_snapshot(snap)
    broken = json.loads(json.dumps(snap))
    del broken["t.sketch_hist"]["series"][0]["value"]["sketch"]
    with pytest.raises(schema.SchemaError):
        schema.validate_metrics_snapshot(broken)


def test_snapshot_is_deep_copy_and_lock_consistent(telemetry):
    """snapshot() under a concurrent writer storm never throws (the
    registry lock covers iteration) and returns an isolated deep copy."""
    c = obs.counter("t.race")
    h = obs.histogram("t.race_hist")
    stop = threading.Event()

    def writer():
        i = 0
        while not stop.is_set():
            c.inc(a=str(i % 7))             # churns the series dict
            h.observe(i % 13 + 0.1, b=str(i % 5))
            i += 1

    threads = [threading.Thread(target=writer) for _ in range(3)]
    for t in threads:
        t.start()
    try:
        for _ in range(200):
            snap = obs.snapshot()           # must not raise mid-iteration
    finally:
        stop.set()
        for t in threads:
            t.join()
    snap = obs.snapshot()
    before = obs.counter("t.race").value(a="0")
    snap["t.race"]["series"][0]["value"] = -999     # mutate the copy
    assert obs.counter("t.race").value(a="0") == before
    assert obs.snapshot()["t.race"]["series"][0]["value"] != -999


# ------------------------------------------------------------------
# ISSUE 10: trace drop accounting + buffered sink
# ------------------------------------------------------------------

def test_dropped_records_counted_and_surfaced(telemetry, tmp_path,
                                              monkeypatch):
    """Records past the in-memory bound are counted (never silently
    swallowed), surfaced in summary(), pinned into the metrics footer —
    and the file sink still receives every one of them."""
    monkeypatch.setattr(obs.tracing, "_MAX_RECORDS", 4)
    path = tmp_path / "trace.jsonl"
    obs.set_sink(str(path))
    for i in range(10):
        obs.event("spam", i=i)
    assert obs.tracing.dropped_records() == 6
    assert obs.counter("obs.trace.dropped_records").value() == 6
    assert len(obs.tracing.records()) == 4
    assert "6 trace records dropped" in obs.summary()
    obs.tracing.close_sink(final_metrics=True)
    recs = [json.loads(l) for l in path.read_text().splitlines()]
    assert sum(r.get("name") == "spam" for r in recs) == 10   # sink complete
    footer = recs[-1]
    assert footer["kind"] == "metrics"
    g = footer["metrics"]["obs.trace.dropped_records_total"]
    assert g["series"][0]["value"] == 6


def test_sink_is_buffered_not_per_record(telemetry, tmp_path):
    """Satellite: the sink coalesces writes — emitting N records costs
    O(N / _SINK_FLUSH_RECORDS) file writes, not N — and flush_sink()
    forces the tail out for live tailing."""
    path = tmp_path / "buf.jsonl"
    obs.set_sink(str(path))

    class _Spy:
        def __init__(self, f):
            self.f, self.writes = f, []

        def write(self, s):
            self.writes.append(s)
            return self.f.write(s)

        def flush(self):
            return self.f.flush()

        def close(self):
            return self.f.close()

    spy = obs.tracing._sink = _Spy(obs.tracing._sink)
    n = 600
    for i in range(n):
        obs.event("b", i=i)
    # coalesced: one write per flush threshold, not one per record
    # (+slack for a time-threshold flush on a very slow machine)
    assert len(spy.writes) <= 2 + n // obs.tracing._SINK_FLUSH_RECORDS
    obs.flush_sink()
    assert sum(s.count("\n") for s in spy.writes) == n
    obs.tracing.close_sink()
    assert len(path.read_text().splitlines()) == n


# ------------------------------------------------------------------
# ISSUE 10: per-request timelines through the serving stack
# ------------------------------------------------------------------
from repro.serving import ContinuousScheduler


def test_request_timeline_continuous(telemetry, tiny, tmp_path):
    """Acceptance: every request minted at submit() is traceable through
    one trace file — submit -> admission -> every engine.stepwise call
    it rode (batched with other requests) -> completion — and every
    stepwise span a request participated in carries its request_id.
    Covers mid-flight admission: r2 joins r1's live batch."""
    model, params = tiny
    eng = GenerationEngine(model, params, EngineConfig(
        method="dndm", steps=4, shared_tau=False))
    path = tmp_path / "serve_trace.jsonl"
    obs.set_sink(str(path))
    sched = ContinuousScheduler(eng, max_batch=2, bucket_len=SEQ, seed=5)
    r1 = sched.submit(SEQ)
    sched.pump()                             # r1 in flight alone
    r2 = sched.submit(SEQ)                   # mid-flight admission
    done = sched.run()
    obs.tracing.close_sink()

    for rid in (r1, r2):
        req = done[rid]
        assert req.request_id.startswith("req-")
        assert req.plan.request_id == req.request_id   # stamped plan
        tl = obs.timeline(req.request_id, path=str(path))
        names = [r["name"] for r in tl if r["kind"] != "metrics"]
        assert "scheduler.submit" in names
        assert "scheduler.admit" in names
        assert "scheduler.complete" in names
        order = [n for n in names if n in
                 ("scheduler.submit", "scheduler.admit",
                  "scheduler.complete")]
        assert order[0] == "scheduler.submit"
        assert order[-1] == "scheduler.complete"
        stepwise = [r for r in tl if r["name"] == "engine.stepwise"]
        assert len(stepwise) == done[rid].steps_executed
        for s in stepwise:
            assert req.request_id in s["attrs"]["request_ids"].split(",")
        # the in-memory view agrees with the file reconstruction
        assert len(obs.timeline(req.request_id)) == len(tl)

    # mid-flight: r2's admit event says it joined a live batch
    tl2 = obs.timeline(done[r2].request_id, path=str(path))
    admit = next(r for r in tl2 if r["name"] == "scheduler.admit")
    assert admit["attrs"]["midflight"] is True
    # batched calls are shared: some stepwise spans name both requests
    both = [r for r in obs.timeline(done[r1].request_id, path=str(path))
            if r["name"] == "engine.stepwise"
            and len(r["attrs"]["request_ids"].split(",")) == 2]
    assert both, "no shared batched call recorded for two live requests"


def test_request_timeline_drain_mode(telemetry, tiny, tmp_path):
    """Drain-mode requests are traceable too: the batch span carries
    request_ids, and nested engine.generate/sampler.step records are
    pulled into the timeline transitively."""
    eng = _engine(tiny, "dndm")
    path = tmp_path / "drain_trace.jsonl"
    obs.set_sink(str(path))
    sched = BatchScheduler(eng, max_batch=4, bucket_len=SEQ)
    rids = [sched.submit(SEQ) for _ in range(2)]
    done = sched.run()
    obs.tracing.close_sink()
    for rid in rids:
        tl = obs.timeline(done[rid].request_id, path=str(path))
        names = {r["name"] for r in tl if r["kind"] != "metrics"}
        assert {"scheduler.submit", "scheduler.admit", "scheduler.batch",
                "engine.generate", "scheduler.complete"} <= names
        assert "sampler.step" in names       # transitive child pickup


# ------------------------------------------------------------------
# ISSUE 10: live exporter (Prometheus text + HTTP endpoints)
# ------------------------------------------------------------------

def test_prometheus_text_round_trips(telemetry):
    """Satellite: the text exposition round-trips through the module's
    own minimal parser — counters, gauges, and histogram summaries with
    quantile labels."""
    obs.counter("t.prom.count", "a counter").inc(3, method="dndm")
    obs.gauge("t.prom.gauge").set(1.25, k="v")
    h = obs.histogram("t.prom.hist")
    for v in (0.1, 0.2, 0.4):
        h.observe(v, op="f")
    text = exporter.prometheus_text()
    assert "# TYPE t_prom_count counter" in text
    assert "# TYPE t_prom_hist summary" in text
    parsed = exporter.parse_prometheus_text(text)
    assert parsed[("t_prom_count", (("method", "dndm"),))] == 3.0
    assert parsed[("t_prom_gauge", (("k", "v"),))] == 1.25
    assert parsed[("t_prom_hist_count", (("op", "f"),))] == 3.0
    assert parsed[("t_prom_hist_sum", (("op", "f"),))] == pytest.approx(0.7)
    sv = obs.snapshot()["t.prom.hist"]["series"][0]["value"]
    for q, field in (("0.5", "p50"), ("0.95", "p95"), ("0.99", "p99")):
        live = parsed[("t_prom_hist", (("op", "f"), ("quantile", q)))]
        assert live == pytest.approx(sv[field], rel=1e-5)


def test_metrics_server_serves_live_scrapes(telemetry):
    """/metrics (Prometheus text) and /snapshot (JSON) on an ephemeral
    port; values reflect the live registry; unknown paths 404."""
    obs.counter("t.live.count").inc(7, x="y")
    srv = exporter.MetricsServer(port=0)
    try:
        with urllib.request.urlopen(srv.url + "/metrics", timeout=5) as r:
            text = r.read().decode()
        parsed = exporter.parse_prometheus_text(text)
        assert parsed[("t_live_count", (("x", "y"),))] == 7.0
        with urllib.request.urlopen(srv.url + "/snapshot", timeout=5) as r:
            snap = json.loads(r.read().decode())
        assert snap["t.live.count"]["series"][0]["value"] == 7
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(srv.url + "/nope", timeout=5)
        assert ei.value.code == 404
    finally:
        srv.stop()


def test_snapshot_writer_atomic_file(telemetry, tmp_path):
    obs.counter("t.snapwrite").inc(2)
    path = tmp_path / "snap.json"
    w = exporter.SnapshotWriter(str(path), interval_s=3600)
    w.stop(final=True)                       # forces one atomic write
    snap = json.loads(path.read_text())
    assert snap["t.snapwrite"]["series"][0]["value"] == 2
    assert not (tmp_path / "snap.json.tmp").exists()


# ------------------------------------------------------------------
# ISSUE 10: SLO budgets + error-budget burn
# ------------------------------------------------------------------

@pytest.fixture()
def slo_budgets():
    yield
    slo.clear()


def test_slo_parse_grammar(slo_budgets):
    got = slo.parse("latency<0.25@0.95, nfe<64@1.0, dndm_c.queue<0.1")
    assert [b.name for b in got] == ["latency<0.25", "nfe<64",
                                    "dndm_c.queue<0.1"]
    assert got[0].objective == 0.95 and got[0].method == "*"
    assert got[1].objective == 1.0
    assert got[2].method == "dndm_c" and got[2].objective == 0.99
    with pytest.raises(ValueError):
        slo.parse("latency")                 # no limit
    with pytest.raises(ValueError):
        slo.parse("walltime<1.0")            # unknown metric
    with pytest.raises(ValueError):
        slo.Budget("latency", 0.1, objective=0.0)


def test_slo_breach_counting_and_burn(telemetry, slo_budgets):
    slo.configure([slo.Budget("latency", 0.1, objective=0.9),
                   slo.Budget("nfe", 8, objective=1.0, method="dndm")])
    for lat in (0.05, 0.05, 0.2):            # 1 of 3 over the limit
        slo.observe_request("dndm", latency_s=lat, queue_s=0.0, nfe=4)
    slo.observe_request("rdm", latency_s=0.05, queue_s=0.0, nfe=99)
    assert obs.counter("scheduler.slo_breaches").value(
        budget="latency<0.1", method="dndm") == 1
    # the method-scoped nfe budget ignored rdm's 99 calls
    assert obs.counter("scheduler.slo_requests").value(
        budget="dndm.nfe<8", method="rdm") == 0
    st = slo.status()
    lat = st["latency<0.1"]
    assert lat["requests"] == 4 and lat["breaches"] == 1
    # allowance = (1-0.9)*4 = 0.4 -> burn = 1/0.4 = 2.5 (budget spent)
    assert lat["burn"] == pytest.approx(2.5)
    assert st["dndm.nfe<8"]["breaches"] == 0
    assert obs.gauge("scheduler.slo_burn").value(
        budget="latency<0.1") == pytest.approx(2.5)


def test_slo_noop_without_budgets(telemetry, slo_budgets):
    assert not slo.active()
    slo.observe_request("dndm", latency_s=9e9, queue_s=9e9, nfe=9e9)
    assert obs.snapshot() == {}              # nothing recorded
    assert slo.status() == {}


def test_scheduler_reports_completed_requests_to_slo(telemetry, tiny,
                                                     slo_budgets):
    """Integration: both schedulers score completions against the active
    budgets — a sky-high latency limit records requests, a zero limit
    records breaches."""
    slo.configure(slo.parse("latency<1e9@0.99,queue<0.0@0.99"))
    eng = _engine(tiny, "dndm_static")
    sched = BatchScheduler(eng, max_batch=4, bucket_len=SEQ)
    n = 3
    for _ in range(n):
        sched.submit(SEQ)
    sched.run()
    assert obs.counter("scheduler.slo_requests").value(
        budget="latency<1e+09", method="dndm_static") == n
    assert obs.counter("scheduler.slo_breaches").value(
        budget="latency<1e+09", method="dndm_static") == 0
    assert obs.counter("scheduler.slo_breaches").value(
        budget="queue<0", method="dndm_static") == n


# ------------------------------------------------------------------
# ISSUE 10: bench-regression gate
# ------------------------------------------------------------------

def _serving_artifact(wall=10.0, rps=5.0, p95=0.4, nfe=100,
                      parity=True, fewer=True):
    mode = {"wall_seconds": wall, "throughput_rps": rps,
            "latency_p50_s": p95 / 2, "latency_p95_s": p95,
            "latency_p99_s": p95 * 1.2, "aggregate_nfe": nfe}
    return {"schema": 2, "kind": "serving",
            "modes": {"drain": dict(mode), "continuous": dict(mode)},
            "comparison": {"solo_parity": parity, "fewer_nfe": fewer}}


def test_regress_identical_and_improved_pass():
    base = _serving_artifact()
    ok, lines = regress.compare(base, _serving_artifact())
    assert ok and not any(l.startswith("REGRESSION") for l in lines)
    better = _serving_artifact(wall=5.0, rps=9.0, p95=0.2, nfe=50)
    ok, _ = regress.compare(base, better)
    assert ok                                # improvements never fail


def test_regress_catches_wall_and_parity_regressions(tmp_path):
    base = _serving_artifact()
    ok, lines = regress.compare(base, _serving_artifact(wall=20.0))
    assert not ok                            # 2x wall > 1.5x tolerance
    assert any("wall_seconds" in l for l in lines
               if l.startswith("REGRESSION"))
    # parity flip is exact-match: fails at any magnitude
    ok, lines = regress.compare(base, _serving_artifact(parity=False))
    assert not ok
    assert any("solo_parity" in l for l in lines
               if l.startswith("REGRESSION"))
    # noise inside tolerance passes
    ok, _ = regress.compare(base, _serving_artifact(wall=13.0, rps=4.0))
    assert ok
    # CLI contract: 0 ok / 1 regression / 2 unreadable
    b, n = tmp_path / "b.json", tmp_path / "n.json"
    b.write_text(json.dumps(base))
    n.write_text(json.dumps(_serving_artifact(wall=20.0)))
    assert regress.main([str(b), str(b)]) == 0
    assert regress.main([str(b), str(n)]) == 1
    assert regress.main([str(b), str(n), "--wall-tol", "2.0"]) == 0
    assert regress.main([str(b), str(tmp_path / "missing.json")]) == 2


def test_regress_bench_kind_and_mismatched_kinds():
    mk = lambda wall: {"schema": 2, "methods": {"dndm": {
        "wall_seconds": wall, "tokens_per_second": 100.0, "nfe": 10}}}
    ok, _ = regress.compare(mk(1.0), mk(1.2))
    assert ok
    ok, lines = regress.compare(mk(1.0), mk(3.0))
    assert not ok
    ok, lines = regress.compare(mk(1.0), _serving_artifact())
    assert not ok and any("kind" in l for l in lines
                          if l.startswith("REGRESSION"))
    # a method missing from NEW is a regression
    gone = {"schema": 2, "methods": {}}
    ok, lines = regress.compare(mk(1.0), gone)
    assert not ok
