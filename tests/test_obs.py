"""Telemetry layer: metric semantics, span nesting/export schema,
per-step reveal-count correctness, the disabled-path overhead guard, and
the engine/scheduler integration (host warm-up split, amortized wall)."""
import time

import jax
import numpy as np
import pytest

from repro import obs
from repro.core.samplers import loop
from repro.obs import schema
from repro.models import Model, ModelConfig
from repro.serving import BatchScheduler, EngineConfig, GenerationEngine

VOCAB, SEQ, STEPS = 12, 8, 4


@pytest.fixture()
def telemetry():
    """Enable obs for one test; always restore the disabled default."""
    obs.metrics.reset()
    obs.tracing.clear()
    obs.enable()
    yield
    obs.metrics.reset()
    obs.tracing.clear()
    obs.disable()


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="obs", arch_type="dense", n_layers=1,
                      d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                      vocab_size=VOCAB, block_pattern=("attn",),
                      bidirectional=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _engine(tiny, method="dndm"):
    model, params = tiny
    return GenerationEngine(model, params, EngineConfig(
        method=method, steps=STEPS, nfe_budget=2))


# ------------------------------------------------------------------
# metrics registry
# ------------------------------------------------------------------

def test_counter_gauge_histogram_semantics(telemetry):
    c = obs.counter("t.count", "help text")
    c.inc(a="x")
    c.inc(2, a="x")
    c.inc(5, a="y")
    assert c.value(a="x") == 3
    assert c.value(a="y") == 5
    assert c.value(a="unseen") == 0

    g = obs.gauge("t.gauge")
    g.set(1.5, k="v")
    g.set(2.5, k="v")                       # overwrites
    assert g.value(k="v") == 2.5

    h = obs.histogram("t.hist")
    for v in (0.1, 0.2, 0.3):
        h.observe(v, op="f")
    s = h.value(op="f")
    assert s["count"] == 3
    assert s["min"] == pytest.approx(0.1)
    assert s["max"] == pytest.approx(0.3)
    assert s["sum"] == pytest.approx(0.6)

    snap = obs.snapshot()
    assert snap["t.count"]["type"] == "counter"
    assert snap["t.count"]["help"] == "help text"
    series = {tuple(s["labels"].items()): s["value"]
              for s in snap["t.count"]["series"]}
    assert series[(("a", "x"),)] == 3
    assert snap["t.hist"]["series"][0]["value"]["mean"] == pytest.approx(0.2)
    # same name, different type -> error
    with pytest.raises(TypeError):
        obs.gauge("t.count")


def test_reset_clears_values_not_instruments(telemetry):
    c = obs.counter("t.reset")
    c.inc(7)
    obs.metrics.reset()
    assert c.value() == 0
    assert obs.counter("t.reset") is c


# ------------------------------------------------------------------
# tracing
# ------------------------------------------------------------------

def test_span_nesting_and_export_schema(telemetry, tmp_path):
    path = tmp_path / "trace.jsonl"
    obs.set_sink(str(path))
    with obs.span("outer", method="dndm") as sp:
        obs.event("tick", i=0, t=np.int32(3))   # numpy scalar coerced
        with obs.span("inner"):
            pass
        sp.set(nfe=4)
    obs.write_metrics_record()
    obs.tracing.close_sink()

    recs = schema.validate_trace_lines(path.read_text().splitlines())
    by_name = {r.get("name"): r for r in recs}
    outer, inner, tick = by_name["outer"], by_name["inner"], by_name["tick"]
    # children point at the enclosing span; the root has no parent
    assert outer["parent_id"] is None
    assert inner["parent_id"] == outer["span_id"]
    assert tick["parent_id"] == outer["span_id"]
    assert tick["attrs"] == {"i": 0, "t": 3}
    # attrs set mid-span are exported; spans carry durations
    assert outer["attrs"] == {"method": "dndm", "nfe": 4}
    assert outer["dur_s"] >= inner["dur_s"] >= 0.0
    assert recs[-1]["kind"] == "metrics"


def test_null_span_when_disabled():
    assert not obs.enabled()
    sp = obs.span("nope", a=1)
    assert sp is obs.tracing.NULL_SPAN
    with sp as s:
        s.set(b=2)                              # no-op, no error
    obs.event("nope")
    assert obs.tracing.records() == []


# ------------------------------------------------------------------
# per-step reveal counts (|R_t|)
# ------------------------------------------------------------------

def test_reveal_series_hand_computed():
    # tau = [3, 1, 3, 2]; unique descending times = [3, 2, 1]
    tau = np.array([[3, 1, 3, 2]])
    times = np.array([3, 2, 1])
    # Algorithm 1 reveals #(tau == t) per step
    assert loop.reveal_series(tau, times, version=1).tolist() == [2, 1, 1]
    # Algorithm 3 re-updates everything already revealed (tau >= t)
    assert loop.reveal_series(tau, times, version=2).tolist() == [2, 3, 4]
    # batch mean: second row reveals all 4 tokens at t=3
    tau2 = np.array([[3, 1, 3, 2], [3, 3, 3, 3]])
    assert loop.reveal_series(tau2, times, version=1).tolist() == [3, 0.5, 0.5]


def test_dndm_generate_records_reveal_series(telemetry, tiny, key):
    eng = _engine(tiny, "dndm")
    out, _ = eng.generate(key, 2, SEQ)
    reveals = out.aux["reveal_counts"]
    # every token is revealed exactly once across the walk
    assert float(np.sum(reveals)) == pytest.approx(SEQ)
    # the series matches a hand recomputation from the returned tau set
    tau = np.asarray(jax.device_get(out.aux["tau"]))
    expect = loop.reveal_series(tau, out.aux["times"], version=1)
    np.testing.assert_allclose(reveals, expect)
    # ... and is exported per step as sampler.step events under the
    # engine.generate span
    recs = obs.tracing.records()
    gen = [r for r in recs if r["kind"] == "span"
           and r["name"] == "engine.generate"]
    steps = [r for r in recs if r["kind"] == "event"
             and r["name"] == "sampler.step"]
    assert gen and gen[0]["attrs"]["nfe"] == out.nfe
    assert gen[0]["attrs"]["cache"] == "miss"
    assert gen[0]["attrs"]["backend"] in ("pallas", "interpret", "reference")
    step_reveals = [r["attrs"]["reveal"] for r in steps]
    # the untimed jit warm-up run is obs-suppressed, so the series shows
    # up exactly once — not doubled by the cache-miss warm-up replay
    assert step_reveals == list(map(float, expect))


# ------------------------------------------------------------------
# engine: jit-cache counters + host warm-up split
# ------------------------------------------------------------------

def test_host_warmup_split(telemetry, tiny, key):
    """First host-sampler call per key warms the per-step jit caches
    untimed; wall_seconds is steady-state and the warm-up surplus is
    reported as compile_seconds (0.0 once warm)."""
    eng = _engine(tiny, "dndm")
    out, wall = eng.generate(key, 2, SEQ)
    assert out.aux["compile_seconds"] >= 0.0
    assert obs.counter("engine.jit_cache.misses").value(
        method="dndm", kind="host") == 1
    out2, wall2 = eng.generate(key, 2, SEQ)
    assert out2.aux["compile_seconds"] == 0.0
    assert obs.counter("engine.jit_cache.hits").value(
        method="dndm", kind="host") == 1
    # warm-up reruns the same PRNG key: outputs identical
    assert (np.asarray(out.tokens) == np.asarray(out2.tokens)).all()
    assert wall >= 0 and wall2 >= 0


def test_suppressed_silences_without_flipping_global(telemetry):
    """obs.suppressed(): instruments and events are silenced inside the
    context (enabled() reads False), the global on-state is untouched,
    and nesting unwinds correctly."""
    c = obs.counter("suppress.probe")
    c.inc()
    with obs.suppressed():
        assert not obs.enabled()
        c.inc()
        obs.event("suppress.nope")
        with obs.suppressed():
            c.inc()
        c.inc()                     # still inside the outer context
    assert obs.enabled()
    c.inc()
    assert c.value() == 2
    assert all(r["name"] != "suppress.nope"
               for r in obs.tracing.records())


def test_cold_warm_metric_equality(telemetry, tiny, key):
    """Regression (cold-key double counting): a jit-cache-miss host call
    runs the sampler twice (untimed warm-up + timed run) but must record
    each per-step metric exactly once — the same counts a warm call
    records.  Pre-fix, every cold call double-counted sampler.step
    events, step/reveal histograms and decode.* counters."""
    eng = _engine(tiny, "dndm")

    def emission_counts():
        h_step = obs.histogram("sampler.step_seconds").value(loop="host")
        h_rev = obs.histogram("sampler.reveal_count").value(
            sampler="dndm", version=1)
        steps = sum(1 for r in obs.tracing.records()
                    if r["kind"] == "event" and r["name"] == "sampler.step")
        return ((h_step or {"count": 0})["count"],
                (h_rev or {"count": 0})["count"], steps)

    out, _ = eng.generate(key, 2, SEQ)          # cold: warm-up + timed
    cold = emission_counts()
    obs.metrics.reset()
    obs.tracing.clear()
    out2, _ = eng.generate(key, 2, SEQ)         # warm: timed run only
    warm = emission_counts()
    assert cold == warm
    assert cold[0] == out.nfe                   # one step record per call
    assert (np.asarray(out.tokens) == np.asarray(out2.tokens)).all()


def test_scan_cache_counters(telemetry, tiny, key):
    eng = _engine(tiny, "dndm_static")
    eng.generate(key, 2, SEQ)
    eng.generate(key, 2, SEQ)
    assert obs.counter("engine.jit_cache.misses").value(
        method="dndm_static", kind="scan") == 1
    assert obs.counter("engine.jit_cache.hits").value(
        method="dndm_static", kind="scan") == 1
    assert obs.counter("engine.nfe").value(method="dndm_static") == 4


# ------------------------------------------------------------------
# scheduler: amortized wall + occupancy metrics
# ------------------------------------------------------------------

def test_scheduler_amortized_wall_and_occupancy(telemetry, tiny):
    eng = _engine(tiny, "dndm_static")
    sched = BatchScheduler(eng, max_batch=4, bucket_len=SEQ)
    rids = [sched.submit(SEQ) for _ in range(3)]
    done = sched.run()
    for rid in rids:
        r = done[rid]
        assert r.batch_size == 3
        assert r.batch_wall > 0
        assert r.wall == pytest.approx(r.batch_wall / 3)
    occ = obs.histogram("scheduler.occupancy").value(method="dndm_static")
    assert occ["count"] == 1
    assert occ["max"] == pytest.approx(3 / 4)   # 3 requests in a 4-bucket
    assert obs.counter("scheduler.padded_rows").value(
        method="dndm_static") == 1
    # the exported batch span carries the post-run attrs (wall/occupancy)
    batch_spans = [r for r in obs.tracing.records()
                   if r["kind"] == "span" and r["name"] == "scheduler.batch"]
    assert batch_spans and {"wall_s", "occupancy", "padded_rows"} <= \
        set(batch_spans[0]["attrs"])
    # nesting: the engine span is a child of the scheduler batch span
    gen = [r for r in obs.tracing.records()
           if r["kind"] == "span" and r["name"] == "engine.generate"]
    assert gen[0]["parent_id"] == batch_spans[0]["span_id"]


# ------------------------------------------------------------------
# disabled-path overhead guard
# ------------------------------------------------------------------

def test_disabled_path_overhead():
    """With telemetry off, an instrumented call site costs one guard
    check — no allocation, no records.  Budget: well under the <2%
    engine.generate regression ceiling (a host step is >=100us of real
    work; we require the full span+event+counter trio to stay under
    10us/op even on a loaded CI machine)."""
    assert not obs.enabled()
    c = obs.counter("t.overhead")
    n = 20_000
    t0 = time.perf_counter()
    for _ in range(n):
        obs.span("x", a=1)
        obs.event("y", b=2)
        c.inc(3, d="z")
    per_op = (time.perf_counter() - t0) / n
    assert per_op < 10e-6, f"disabled telemetry costs {per_op * 1e6:.2f}us"
    assert obs.tracing.records() == []
    assert c.value(d="z") == 0
    assert obs.snapshot() == {}


# ------------------------------------------------------------------
# schema validator
# ------------------------------------------------------------------

def test_schema_rejects_malformed_trace():
    with pytest.raises(schema.SchemaError):
        schema.validate_trace_lines(['{"kind": "span", "name": "x"}'])
    with pytest.raises(schema.SchemaError):
        schema.validate_trace_lines(["not json"])
    # a valid line passes
    ok = ('{"kind": "event", "name": "e", "ts": 1.0, "span_id": 1, '
          '"parent_id": null, "attrs": {}}')
    assert len(schema.validate_trace_lines([ok])) == 1


def test_schema_rejects_malformed_bench():
    with pytest.raises(schema.SchemaError):
        schema.validate_bench({"schema": 1})
    good = {
        "schema": 2, "jax_backend": "cpu", "quick": True,
        "config": {"batch": 8, "seq": 32, "steps": 16},
        "methods": {"dndm": {
            "noise": "absorbing", "kind": "host", "wall_seconds": 0.1,
            "compile_seconds": 0.0, "nfe": 10, "tokens_per_second": 100.0,
            "us_per_nfe": 9.0,
            "metrics": {"jit_cache_hits": 1, "jit_cache_misses": 1}}},
        "telemetry": {"enabled": True, "trace": None, "metrics": {}},
    }
    schema.validate_bench(good)                  # no raise
    bad = {**good, "methods": {}}
    with pytest.raises(schema.SchemaError):
        schema.validate_bench(bad)
