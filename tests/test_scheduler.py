"""Serving-layer shape hygiene: batch-bucket padding in the scheduler
(one compiled sampler per bucket, not per queue size) and the engine's
compile/execute timing split."""
import jax
import numpy as np
import pytest

from repro.models import Model, ModelConfig
from repro.serving import BatchScheduler, EngineConfig, GenerationEngine

VOCAB, SEQ, STEPS = 12, 8, 4


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="sched", arch_type="dense", n_layers=1,
                      d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                      vocab_size=VOCAB, block_pattern=("attn",),
                      bidirectional=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _engine(tiny):
    model, params = tiny
    return GenerationEngine(model, params, EngineConfig(
        method="dndm_static", steps=STEPS, nfe_budget=2))


def test_batch_bucket_rounding(tiny):
    sched = BatchScheduler(_engine(tiny), max_batch=6, bucket_len=SEQ)
    assert [sched.batch_bucket(n) for n in (1, 2, 3, 4, 5, 6)] == \
        [1, 2, 4, 4, 6, 6]


def test_one_cache_entry_per_bucket(tiny):
    """Queues of different sizes within a power-of-two bucket share one
    compiled sampler — no per-queue-size retracing."""
    eng = _engine(tiny)
    sched = BatchScheduler(eng, max_batch=8, bucket_len=SEQ)
    ids3 = [sched.submit(SEQ) for _ in range(3)]
    sched.run()
    assert len(eng._jit_cache) == 1            # batch padded 3 -> 4
    ids4 = [sched.submit(SEQ) for _ in range(4)]
    sched.run()
    assert len(eng._jit_cache) == 1            # 4 hits the same bucket
    ids2 = [sched.submit(SEQ) for _ in range(2)]
    sched.run()
    assert len(eng._jit_cache) == 2            # 2 is a new bucket
    done = sched.done
    for rid in ids3 + ids4 + ids2:
        assert done[rid].result.shape == (SEQ,)
        toks = np.asarray(done[rid].result)
        assert (0 <= toks).all() and (toks < VOCAB).all()


def test_wall_amortized_across_batch(tiny):
    """A batch runs once for all its requests: each Request records the
    per-request share in ``wall`` and the totals in ``batch_wall`` /
    ``batch_size`` (telemetry off — these are core scheduler fields)."""
    sched = BatchScheduler(_engine(tiny), max_batch=8, bucket_len=SEQ)
    rids = [sched.submit(SEQ) for _ in range(3)]
    done = sched.run()
    for rid in rids:
        r = done[rid]
        assert r.batch_size == 3
        assert r.batch_wall > 0.0
        assert r.wall == pytest.approx(r.batch_wall / 3)


def test_compile_seconds_reported_separately(tiny, key):
    """Cache miss: compile_seconds > 0 and excluded from wall.  Cache hit:
    compile_seconds == 0."""
    eng = _engine(tiny)
    out, wall = eng.generate(key, 2, SEQ)
    assert out.aux["compile_seconds"] > 0.0
    out2, wall2 = eng.generate(key, 2, SEQ)
    assert out2.aux["compile_seconds"] == 0.0
    # AOT-compiled path is deterministic: same key, same tokens
    assert (np.asarray(out.tokens) == np.asarray(out2.tokens)).all()
    assert wall >= 0 and wall2 >= 0
