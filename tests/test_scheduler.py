"""Serving-layer shape hygiene: batch-bucket padding in the scheduler
(one compiled sampler per bucket, not per queue size) and the engine's
compile/execute timing split."""
import jax
import numpy as np
import pytest

from repro.models import Model, ModelConfig
from repro.serving import BatchScheduler, EngineConfig, GenerationEngine

VOCAB, SEQ, STEPS = 12, 8, 4


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="sched", arch_type="dense", n_layers=1,
                      d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                      vocab_size=VOCAB, block_pattern=("attn",),
                      bidirectional=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _engine(tiny):
    model, params = tiny
    return GenerationEngine(model, params, EngineConfig(
        method="dndm_static", steps=STEPS, nfe_budget=2))


def test_batch_bucket_rounding(tiny):
    sched = BatchScheduler(_engine(tiny), max_batch=6, bucket_len=SEQ)
    assert [sched.batch_bucket(n) for n in (1, 2, 3, 4, 5, 6)] == \
        [1, 2, 4, 4, 6, 6]


def test_one_cache_entry_per_bucket(tiny):
    """Queues of different sizes within a power-of-two bucket share one
    compiled sampler — no per-queue-size retracing."""
    eng = _engine(tiny)
    sched = BatchScheduler(eng, max_batch=8, bucket_len=SEQ)
    ids3 = [sched.submit(SEQ) for _ in range(3)]
    sched.run()
    assert len(eng._jit_cache) == 1            # batch padded 3 -> 4
    ids4 = [sched.submit(SEQ) for _ in range(4)]
    sched.run()
    assert len(eng._jit_cache) == 1            # 4 hits the same bucket
    ids2 = [sched.submit(SEQ) for _ in range(2)]
    sched.run()
    assert len(eng._jit_cache) == 2            # 2 is a new bucket
    done = sched.done
    for rid in ids3 + ids4 + ids2:
        assert done[rid].result.shape == (SEQ,)
        toks = np.asarray(done[rid].result)
        assert (0 <= toks).all() and (toks < VOCAB).all()


def test_wall_amortized_across_batch(tiny):
    """A batch runs once for all its requests: each Request records the
    per-request share in ``wall`` and the totals in ``batch_wall`` /
    ``batch_size`` (telemetry off — these are core scheduler fields)."""
    sched = BatchScheduler(_engine(tiny), max_batch=8, bucket_len=SEQ)
    rids = [sched.submit(SEQ) for _ in range(3)]
    done = sched.run()
    for rid in rids:
        r = done[rid]
        assert r.batch_size == 3
        assert r.batch_wall > 0.0
        assert r.wall == pytest.approx(r.batch_wall / 3)


def test_compile_seconds_reported_separately(tiny, key):
    """Cache miss: compile_seconds > 0 and excluded from wall.  Cache hit:
    compile_seconds == 0."""
    eng = _engine(tiny)
    out, wall = eng.generate(key, 2, SEQ)
    assert out.aux["compile_seconds"] > 0.0
    out2, wall2 = eng.generate(key, 2, SEQ)
    assert out2.aux["compile_seconds"] == 0.0
    # AOT-compiled path is deterministic: same key, same tokens
    assert (np.asarray(out.tokens) == np.asarray(out2.tokens)).all()
    assert wall >= 0 and wall2 >= 0


# ---------------------------------------------------------------------
# ContinuousScheduler (ISSUE 8): NFE-aware continuous batching
# ---------------------------------------------------------------------
from repro import obs
from repro.serving import ContinuousScheduler


@pytest.fixture()
def telemetry():
    """Enable obs for one test; always restore the disabled default."""
    obs.metrics.reset()
    obs.enable()
    yield
    obs.metrics.reset()
    obs.disable()


def _dndm_engine(tiny, steps=STEPS):
    model, params = tiny
    return GenerationEngine(model, params, EngineConfig(
        method="dndm", steps=steps, shared_tau=False))


def test_continuous_solo_parity_real_model(tiny):
    """Acceptance: per-request tokens are bitwise identical to a solo
    ``engine.generate`` under the request's own key (same tau set and
    per-step key stream).  dndm/dndm2 decode by adjusted-logit
    argmax/Gumbel-max over per-row noise, which is robust to the ~1e-6
    cross-batch-shape logit jitter of a real transformer (score-*ranked*
    methods are covered by the elementwise-model property tests)."""
    eng = _dndm_engine(tiny, steps=6)
    for method in ("dndm", "dndm2"):
        sched = ContinuousScheduler(eng, max_batch=4, bucket_len=SEQ,
                                    seed=3)
        rids = [sched.submit(n, method=method)
                for n in (SEQ, 5, SEQ, 6, SEQ)]
        done = sched.run()
        assert sorted(done) == sorted(rids)
        for rid in rids:
            r = done[rid]
            solo, _ = eng.generate(r.key, 1, SEQ, method=method)
            np.testing.assert_array_equal(
                np.asarray(solo.tokens)[0, : r.length],
                np.asarray(r.result), err_msg=f"{method} rid {rid}")
            assert r.steps_executed + r.steps_skipped == 6
            assert r.nfe == len(r.plan.times)


def test_continuous_fewer_calls_than_drain(tiny):
    """With independent tau sets, drain pays |union of member schedules|
    per batch; continuous pays the per-cohort max — strictly fewer
    batched network calls on the same seeded workload."""
    eng = _dndm_engine(tiny, steps=8)
    lengths = [SEQ, 6, SEQ, 5, SEQ, 7]

    drain = BatchScheduler(eng, max_batch=4, bucket_len=SEQ, seed=11)
    for n in lengths:
        drain.submit(n, method="dndm")
    drain_done = drain.run()
    drain_calls = sum({r.t_admit: r.nfe
                       for r in drain_done.values()}.values())

    cont = ContinuousScheduler(eng, max_batch=4, bucket_len=SEQ, seed=11)
    for n in lengths:
        cont.submit(n, method="dndm")
    cont.run()
    assert cont.total_calls < drain_calls
    # and never worse than the sum of solo schedules
    assert cont.total_calls <= sum(
        r.steps_executed for r in cont.done.values())


def test_continuous_midflight_admission_and_metrics(tiny, telemetry):
    """Admissions into a live batch are counted, skipped steps land in
    scheduler.steps_skipped, and queue latency/service histograms fill
    under mode=continuous."""
    eng = _dndm_engine(tiny, steps=6)
    sched = ContinuousScheduler(eng, max_batch=2, bucket_len=SEQ, seed=5)
    r1 = sched.submit(SEQ)
    sched.pump()                 # r1 in flight alone
    r2 = sched.submit(SEQ)       # lands in a live batch
    done = sched.run()
    assert sorted(done) == [r1, r2]
    assert obs.counter("scheduler.admissions_midflight").value(
        method="dndm") >= 1
    skipped = sum(r.steps_skipped for r in done.values())
    assert obs.counter("scheduler.steps_skipped").value(
        method="dndm") == skipped
    assert obs.counter("engine.stepwise_calls").value(
        method="dndm") == sched.total_calls
    snap = obs.snapshot()
    lat_modes = {tuple(s["labels"].items())
                 for s in snap["scheduler.queue_latency_seconds"]["series"]}
    assert (("mode", "continuous"),) in lat_modes
    svc_modes = {tuple(s["labels"].items())
                 for s in snap["scheduler.service_seconds"]["series"]}
    assert (("mode", "continuous"),) in svc_modes


# ---------------------------------------------------------------------
# ISSUE 9: continuous batching for the whole registry + the bugs it
# flushed out (prefix pad token, group starvation, conditional rows)
# ---------------------------------------------------------------------
import jax.numpy as jnp

from repro.core.samplers import registry


class _ElemCfg:
    vocab_size = VOCAB


class _ElemModel:
    """Purely elementwise denoiser (row b's logits depend only on row b's
    tokens/prefix/time), so trajectories are batch-shape-invariant and
    stepwise-vs-solo parity is exact for every method — including the
    score-ranked ones a real transformer's ~1e-6 cross-batch logit
    jitter would perturb."""

    cfg = _ElemCfg()

    def init(self, key):
        return {}

    def denoise_fn(self, params, _cond=None):
        def fn(x_t, t, cond):
            k = jnp.arange(VOCAB, dtype=jnp.float32)
            n = jnp.arange(x_t.shape[-1], dtype=jnp.float32)
            t_ = jnp.asarray(t, jnp.float32).reshape(-1, 1, 1)
            base = jnp.sin(x_t[..., None].astype(jnp.float32) * 0.37
                           + k * 1.11 + n[None, :, None] * 0.23
                           + t_ * 2.9) * 4.0
            if cond is not None:
                p = cond["prefix_tokens"].astype(jnp.float32)
                base = base + jnp.cos(p * 0.61).sum(-1)[:, None, None] * 2.0
            return base
        return fn


def _elem_engine(noise_kind="absorbing"):
    model = _ElemModel()
    return GenerationEngine(model, model.init(None), EngineConfig(
        method="dndm", steps=6, noise_kind=noise_kind, shared_tau=False,
        nfe_budget=3, ddim_stride=2))


def test_every_registered_method_is_stepwise_capable():
    """Acceptance: the whole registry serves through ContinuousScheduler
    — every spec carries both a schedule_fn and a stepwise_step."""
    for name in registry.names():
        spec = registry.get(name)
        assert spec.schedule_fn is not None, name
        assert spec.stepwise_step is not None, name


@pytest.mark.parametrize("noise_kind", ["absorbing", "multinomial"])
def test_stepwise_full_registry_solo_parity(noise_kind):
    """Every registered method, served through the rolling stepwise
    batch, reproduces its solo ``engine.generate(key, 1, N)`` run
    bitwise — rows at different diffusion times, different methods
    pumped round-robin, mid-flight admissions included."""
    eng = _elem_engine(noise_kind)
    methods = registry.names(noise_kind)
    sched = ContinuousScheduler(eng, max_batch=3, bucket_len=SEQ, seed=7)
    rids = {m: sched.submit(SEQ, method=m) for m in methods}
    done = sched.run()
    assert sorted(done) == sorted(rids.values())
    for m, rid in rids.items():
        r = done[rid]
        solo, _ = eng.generate(r.key, 1, SEQ, method=m)
        np.testing.assert_array_equal(
            np.asarray(solo.tokens)[0], np.asarray(r.result),
            err_msg=f"{m} diverged from its solo replay")
        assert r.nfe == len(r.plan.times)


def test_stepwise_conditional_rows_solo_parity():
    """Conditional (prefix) requests no longer force drain mode: the
    continuous scheduler groups them by (method, prefix length) into
    conditional runners, and each row still reproduces the solo
    conditional run bitwise (prefixes are never padded in-batch)."""
    eng = _elem_engine()
    sched = ContinuousScheduler(eng, max_batch=2, bucket_len=SEQ, seed=9)
    rng = np.random.default_rng(0)
    subs = []
    for m, P in [("dndm", 3), ("rdm_k", 4), ("dndm_topk", 3), ("d3pm", 4)]:
        pre = rng.integers(0, VOCAB - 1, size=P).astype(np.int32)
        subs.append((sched.submit(SEQ, prefix=pre, method=m), m, pre))
    done = sched.run()
    assert sorted(done) == sorted(rid for rid, _, _ in subs)
    for rid, m, pre in subs:
        r = done[rid]
        solo, _ = eng.generate(r.key, 1, SEQ, method=m,
                               cond={"prefix_tokens": jnp.asarray(pre)[None]})
        np.testing.assert_array_equal(
            np.asarray(solo.tokens)[0], np.asarray(r.result),
            err_msg=f"conditional {m} (P={len(pre)}) diverged from solo")


def test_round_robin_no_group_starvation():
    """Regression: the old scheduler pinned one "current" method group
    until its runner fully drained, so a steady single-method arrival
    stream starved every other group forever.  Groups with work are now
    served round-robin: under an adversarial steady stream of method A,
    a queued method-B request still completes within its fairness bound
    (one B call per rotation => ~2x its schedule length in pumps)."""
    eng = _elem_engine()
    sched = ContinuousScheduler(eng, max_batch=2, bucket_len=SEQ, seed=1)
    sched.submit(SEQ, method="dndm")
    sched.submit(SEQ, method="dndm")
    sched.pump()                        # dndm runner is live
    rid_b = sched.submit(SEQ, method="rdm")
    n_calls_b = len(sched.queue[-1].plan.times)
    pumps = 0
    while rid_b not in sched.done:
        sched.submit(SEQ, method="dndm")   # keep A's queue non-empty
        assert sched.pump()
        pumps += 1
        assert pumps <= 2 * n_calls_b + 2, "rdm starved by the dndm stream"
    done = sched.run()                  # drain the adversarial backlog
    assert sorted(done) == list(range(1, sched._rid + 1))


def test_drain_prefix_padded_with_noise_pad_token(tiny, monkeypatch):
    """Regression: BatchScheduler left-padded short prefixes (and free
    bucket rows) with token 0 — a real vocab token — conditioning those
    rows on spurious content.  Mixed-length prefixes must pad with the
    noise pad token ([MASK] for absorbing diffusion)."""
    eng = _engine(tiny)
    assert eng.noise.pad_id == eng.noise.mask_id    # absorbing: [MASK]
    seen = {}
    orig = eng.generate

    def spy(key, batch, N, cond=None, method=None):
        seen["cond"] = cond
        return orig(key, batch, N, cond=cond, method=method)

    monkeypatch.setattr(eng, "generate", spy)
    sched = BatchScheduler(eng, max_batch=4, bucket_len=SEQ)
    r1 = sched.submit(SEQ, prefix=np.array([1, 2], np.int32))
    r2 = sched.submit(SEQ, prefix=np.array([3, 4, 5, 6, 7], np.int32))
    r3 = sched.submit(SEQ, prefix=np.array([8], np.int32))
    done = sched.run()
    assert sorted(done) == [r1, r2, r3]
    pre = np.asarray(seen["cond"]["prefix_tokens"])
    m = eng.noise.mask_id
    assert pre.shape == (4, 5)          # 3 requests -> bucket of 4, P=5
    np.testing.assert_array_equal(pre[0], [m, m, m, 1, 2])
    np.testing.assert_array_equal(pre[1], [3, 4, 5, 6, 7])
    np.testing.assert_array_equal(pre[2], [m, m, m, m, 8])
    np.testing.assert_array_equal(pre[3], [m] * 5)  # padded bucket row


def test_mixed_method_queue_buckets_fifo(tiny):
    """The one-pass ``_buckets`` grouping: methods keep first-arrival
    order, FIFO within each method, chunks capped at max_batch — same
    behavior the per-pop rescan had, without the O(n^2) drain."""
    eng = _engine(tiny)
    sched = BatchScheduler(eng, max_batch=2, bucket_len=SEQ)
    pattern = ["dndm_static", "dndm", "dndm_static", "dndm_static",
               "dndm", "dndm_static"]
    rids = [sched.submit(SEQ, method=m) for m in pattern]
    batches = sched._buckets()
    assert sched.queue == []
    got = [[r.rid for r in b] for b in batches]
    # dndm_static arrived first: its FIFO chunks come first
    assert got == [[rids[0], rids[2]], [rids[3], rids[5]],
                   [rids[1], rids[4]]]
    # the split batches still run to completion
    sched.queue = [r for b in batches for r in b]
    done = sched.run()
    assert sorted(done) == sorted(rids)
    for rid in rids:
        assert done[rid].result.shape == (SEQ,)
