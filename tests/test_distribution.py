"""Sharding rules + analysis plumbing (no 512-device requirement: rules
are pure functions of mesh metadata; we use small host meshes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import repro.configs as C
from repro.launch import analysis
from repro.launch.sharding import ShardingPolicy, param_spec, cache_spec


class FakeMesh:
    """Mesh metadata stand-in (axis sizes only; rules never need devices)."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})
POL = ShardingPolicy()
CFG = C.get("mixtral-8x7b")


def test_param_rules_tp():
    assert param_spec("unit/b0/attn/wq", (32, 4096, 4096), MESH, POL,
                      CFG) == P(None, None, "model")
    assert param_spec("unit/b0/attn/wo", (32, 4096, 4096), MESH, POL,
                      CFG) == P(None, "model")
    assert param_spec("unit/b0/mlp/down", (32, 14336, 4096), MESH, POL,
                      CFG) == P(None, "model")
    assert param_spec("embed", (32000, 4096), MESH, POL, CFG) == P("model")
    assert param_spec("head", (4096, 32000), MESH, POL, CFG) == \
        P(None, "model")
    assert param_spec("ln_f/scale", (4096,), MESH, POL, CFG) == P()


def test_param_rules_moe_fallback():
    # mixtral: 8 experts, model=16 => not divisible => ff tensor parallel
    assert param_spec("unit/b0/moe/up", (32, 8, 4096, 14336), MESH, POL,
                      CFG) == P(None, None, None, "model")
    assert param_spec("unit/b0/moe/down", (32, 8, 14336, 4096), MESH, POL,
                      CFG) == P(None, None, "model")
    # llama4: 128 experts => expert-parallel
    cfg4 = C.get("llama4-maverick-400b-a17b")
    assert param_spec("unit/b0/moe/up", (48, 128, 5120, 8192), MESH, POL,
                      cfg4) == P(None, "model")


def _norm(spec):
    """Normalize PartitionSpec entries to tuples, drop trailing Nones."""
    out = []
    for e in spec:
        out.append(tuple(e) if isinstance(e, (tuple, list))
                   else ((e,) if e else None))
    while out and out[-1] is None:
        out.pop()
    return tuple(out)


def test_param_rules_nondivisible_replicates():
    # a dim not divisible by the model axis must replicate
    spec = param_spec("unit/b0/attn/wq", (30, 3072, 100), MESH, POL, CFG)
    assert _norm(spec) == ()


def test_cache_specs():
    # decode_32k: B=128 shardable on data
    spec = cache_spec(MESH, (32, 128, 32768, 8, 128), 128, POL, "kv")
    assert _norm(spec)[1] == ("data",)
    # long_500k: B=1 -> context parallelism on seq
    spec = cache_spec(MESH, (9, 1, 524288, 32, 80), 1, POL, "kv")
    assert _norm(spec)[2] == ("data",)
    # multi-pod batch axes
    spec = cache_spec(MESH3, (32, 128, 32768, 8, 128), 128, POL, "kv")
    assert _norm(spec)[1] == ("pod", "data")


def test_collective_parser():
    hlo = """
  %all-reduce.5 = f32[16,512,1024]{2,1,0} all-reduce(%x), replica_groups=[16,16]<=[256]
  %fusion = bf16[8,8]{1,0} fusion(%all-reduce.5)
  %ag = bf16[4,1024]{1,0} all-gather(%y), dimensions={0}
  %cp = u32[] collective-permute(%z)
  %not-a-coll = f32[2,2]{1,0} add(%a, %b)
"""
    out = analysis.collective_bytes(hlo)
    assert out["all-reduce"] == 16 * 512 * 1024 * 4
    assert out["all-gather"] == 4 * 1024 * 2
    assert out["count"] == 3


def test_roofline_terms():
    cost = {"flops": 197e12, "bytes accessed": 819e9}
    coll = {"all-reduce": int(100e9), "count": 1}
    t = analysis.roofline(cost, coll, 256, model_flops=197e12 * 256)
    assert abs(t.compute_s - 1.0) < 1e-9
    assert abs(t.memory_s - 1.0) < 1e-9
    assert abs(t.collective_s - 1.0) < 1e-9
    assert abs(t.useful_ratio - 1.0) < 1e-9
    assert t.dominant in ("compute", "memory", "collective")


def test_scan_correction_only_for_slstm():
    xl = C.get("xlstm-350m")
    assert analysis.scan_correction(xl, 256, 4096, "train") > 0
    dense = C.get("tinyllama-1.1b")
    assert analysis.scan_correction(dense, 256, 4096, "train") == 0.0


def test_mesh_helpers():
    from repro.launch.mesh import axis_size, batch_axes
    m = FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert batch_axes(m) == ("pod", "data")
    assert axis_size(m, "pod", "data") == 32


# ---------------------------------------------------------------------
# Transition-law conformance (ISSUE 8): the sampled transition-time
# marginals must match the analytic laws of the paper.
#
# Tolerance rationale (all seeds fixed, so every run sees the same
# draws — thresholds guard against *implementation* drift, not luck):
#
# * chi-square: with the law correct the statistic is asymptotically
#   chi2 with dof = (#bins - 1), mean dof and sd sqrt(2 dof).  We accept
#   up to dof + 4 sd — one-sided false-alarm ~3e-5 were the seed free —
#   while an off-by-one in the time indexing (mass shifted by one bin)
#   moves the statistic by O(n/T), orders of magnitude past it.
# * KS: the Kolmogorov critical value is sqrt(-ln(a/2)/2)/sqrt(n);
#   a = 1e-4 gives 2.22/sqrt(n).  We add 2e-3 slack for the trapezoid
#   quadrature error of the scipy-free _beta_cdf oracle.
# ---------------------------------------------------------------------
from repro.core import schedules, transition
from repro.core.transition import _beta_cdf


def _chi_square(counts: np.ndarray, expected: np.ndarray,
                min_expected: float = 8.0) -> tuple[float, int]:
    """Pearson statistic with small-expectation bins pooled (the chi2
    approximation needs every expected count above a handful)."""
    stat, dof, o_acc, e_acc = 0.0, 0, 0.0, 0.0
    for o, e in zip(counts, expected):
        o_acc += o
        e_acc += e
        if e_acc >= min_expected:
            stat += (o_acc - e_acc) ** 2 / e_acc
            dof += 1
            o_acc = e_acc = 0.0
    if e_acc > 0:       # fold the remainder into the last pooled bin
        stat += (o_acc - e_acc) ** 2 / max(e_acc, min_expected)
        dof += 1
    return stat, dof - 1


def test_thm36_finite_t_marginal_chi_square():
    """Theorem 3.6: P(tau = t) = alpha_{t-1} - alpha_t.  The categorical
    sampler must reproduce exactly the schedule's transition_probs."""
    T, n = 50, 20_000
    dist = transition.from_schedule(schedules.linear(T))
    tau = np.asarray(dist.sample(jax.random.PRNGKey(0), (n,)))
    assert tau.min() >= 1 and tau.max() <= T
    counts = np.bincount(tau, minlength=T + 1)[1:].astype(float)
    stat, dof = _chi_square(counts, n * dist.probs)
    assert stat < dof + 4 * np.sqrt(2 * dof), (stat, dof)


def test_beta_approx_marginal_chi_square():
    """beta_approx discretizes Beta(a, b) by CDF differencing at the bin
    edges k/T (paper §3.2) and samples the resulting categorical: the
    analytic bin masses are F(k/T) - F((k-1)/T), recomputed here from
    the quadrature CDF independently of the TransitionDist internals."""
    T, a, b, n = 40, 15.0, 7.0, 20_000
    dist = transition.beta_approx(T, a, b)
    tau = np.asarray(dist.sample(jax.random.PRNGKey(1), (n,)))
    assert tau.min() >= 1 and tau.max() <= T
    counts = np.bincount(tau, minlength=T + 1)[1:].astype(float)
    expected = np.diff(_beta_cdf(np.arange(T + 1) / T, a, b))
    stat, dof = _chi_square(counts, n * expected)
    assert stat < dof + 4 * np.sqrt(2 * dof), (stat, dof)


def test_continuous_beta_ks():
    """DNDM-C timestamps: sample_continuous ~ Beta(a, b) on (0, 1]."""
    a, b, n = 15.0, 7.0, 4_000
    cdist = transition.beta_continuous(a, b)
    x = np.sort(np.asarray(
        cdist.sample_continuous(jax.random.PRNGKey(2), (n,))))
    F = _beta_cdf(x, a, b)
    ecdf_hi = np.arange(1, n + 1) / n
    ks = max(np.abs(ecdf_hi - F).max(), np.abs(F - (ecdf_hi - 1 / n)).max())
    assert ks < 2.22 / np.sqrt(n) + 2e-3, ks


def test_continuous_from_discrete_law_ks():
    """A probs-backed law samples continuous times by inverse-CDF on the
    grid plus uniform within-bin jitter: the CDF is the piecewise-linear
    interpolant of cumsum(probs) at the bin edges t/T."""
    T, n = 50, 4_000
    dist = transition.from_schedule(schedules.cosine(T))
    x = np.sort(np.asarray(
        dist.sample_continuous(jax.random.PRNGKey(3), (n,))))
    knots_x = np.arange(T + 1) / T
    knots_F = np.concatenate([[0.0], np.cumsum(dist.probs)])
    F = np.interp(x, knots_x, knots_F)
    ecdf_hi = np.arange(1, n + 1) / n
    ks = max(np.abs(ecdf_hi - F).max(), np.abs(F - (ecdf_hi - 1 / n)).max())
    assert ks < 2.22 / np.sqrt(n) + 2e-3, ks
