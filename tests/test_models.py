"""Model zoo behaviour: decode==forward consistency, bidirectional mode,
MoE dispatch invariants, attention impl equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.models import Model, ModelConfig
from repro.models import attention, moe
from repro.models.config import dense_pattern


def tiny(pattern, **kw):
    base = dict(name="t", arch_type="x", n_layers=len(pattern), d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=50,
                block_pattern=pattern, ssm_state=16, ssm_head_dim=32,
                ssd_chunk=8, lstm_heads=2, sliding_window=8)
    base.update(kw)
    return ModelConfig(**base)


FAMILIES = {
    "dense": tiny(("attn",) * 2),
    "swa": tiny(("swa",) * 2),
    "moe": tiny(("moe",) * 2, n_experts=4, experts_per_token=2,
                capacity_factor=8.0),
    "mamba": tiny(("mamba2",) * 2),
    "xlstm": tiny(("mlstm", "slstm")),
    "zamba": tiny(("mamba2", "shared_attn") * 2),
}


@pytest.mark.parametrize("fam", list(FAMILIES))
def test_decode_matches_forward(fam, key):
    cfg = FAMILIES[fam]
    m = Model(cfg)
    p = m.init(key)
    tok = jax.random.randint(jax.random.fold_in(key, 1), (2, 10), 0, 50)
    full, _ = m.forward(p, tok, None, causal=True)
    cache = m.init_cache(2, 10)
    step = jax.jit(m.decode_step)
    outs = []
    for i in range(10):
        lg, cache = step(p, tok[:, i:i + 1], cache, jnp.asarray(i))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-4, rtol=2e-3)


@pytest.mark.parametrize("fam", list(FAMILIES))
def test_bidirectional_uses_future_context(fam, key):
    """In denoiser mode, changing a future token changes past logits."""
    cfg = FAMILIES[fam]
    m = Model(cfg)
    p = m.init(key)
    tok = jax.random.randint(jax.random.fold_in(key, 2), (1, 10), 0, 50)
    tok2 = tok.at[0, -1].set((tok[0, -1] + 1) % 50)
    a, _ = m.forward(p, tok, None, causal=False)
    b, _ = m.forward(p, tok2, None, causal=False)
    assert float(jnp.abs(a[0, 0] - b[0, 0]).max()) > 1e-6
    # and causal mode must NOT leak the future
    a, _ = m.forward(p, tok, None, causal=True)
    b, _ = m.forward(p, tok2, None, causal=True)
    assert float(jnp.abs(a[0, 0] - b[0, 0]).max()) < 1e-6


def test_sliding_window_locality(key):
    """SWA: tokens beyond the window cannot influence the query."""
    cfg = tiny(("swa",) * 1, sliding_window=4)
    m = Model(cfg)
    p = m.init(key)
    tok = jax.random.randint(jax.random.fold_in(key, 3), (1, 16), 0, 50)
    tok2 = tok.at[0, 0].set((tok[0, 0] + 1) % 50)
    a, _ = m.forward(p, tok, None, causal=True)
    b, _ = m.forward(p, tok2, None, causal=True)
    # position 15 is > 4 steps away from position 0
    assert float(jnp.abs(a[0, 15] - b[0, 15]).max()) < 1e-6
    assert float(jnp.abs(a[0, 2] - b[0, 2]).max()) > 1e-7


def test_attention_impl_equivalence(key):
    """einsum / blocked / pallas give the same attention output."""
    outs = {}
    tok = jax.random.randint(jax.random.fold_in(key, 4), (2, 24), 0, 50)
    for impl in ("einsum", "blocked", "pallas"):
        cfg = tiny(("attn",) * 2, attn_impl=impl, attn_block_q=8,
                   attn_block_k=8)
        m = Model(cfg)
        p = m.init(jax.random.PRNGKey(11))
        logits, _ = m.forward(p, tok, None, causal=True)
        outs[impl] = np.asarray(logits)
    np.testing.assert_allclose(outs["einsum"], outs["blocked"],
                               atol=3e-4, rtol=3e-3)
    np.testing.assert_allclose(outs["einsum"], outs["pallas"],
                               atol=3e-4, rtol=3e-3)


def test_moe_dispatch_is_weighted_permutation(key):
    """With ample capacity, MoE output == dense per-token expert mix."""
    cfg = tiny(("moe",), n_experts=4, experts_per_token=2,
               capacity_factor=16.0)
    params = moe.init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 5), (2, 6, 64))
    y, aux = moe.apply(params, x, cfg)
    assert aux["dropped_frac"] == 0.0
    # dense reference: run every expert on every token, mix by gates
    logits = (x.reshape(-1, 64) @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gv, ei = jax.lax.top_k(probs, 2)
    gv = gv / gv.sum(-1, keepdims=True)
    xt = x.reshape(-1, 64)
    h = jnp.einsum("td,edf->tef", xt, params["gate"])
    hu = jnp.einsum("td,edf->tef", xt, params["up"])
    act = jax.nn.silu(h) * hu
    out_all = jnp.einsum("tef,efd->ted", act, params["down"])
    ref = jnp.zeros_like(xt)
    for kk in range(2):
        sel = jnp.take_along_axis(out_all, ei[:, kk][:, None, None],
                                  axis=1)[:, 0]
        ref = ref + sel * gv[:, kk][:, None]
    np.testing.assert_allclose(np.asarray(y.reshape(-1, 64)),
                               np.asarray(ref), atol=1e-4, rtol=1e-3)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=8, deadline=None)
def test_moe_never_nan(seed):
    cfg = tiny(("moe",), n_experts=4, experts_per_token=2)
    k = jax.random.PRNGKey(seed)
    params = moe.init(k, cfg)
    x = jax.random.normal(jax.random.fold_in(k, 1), (1, 8, 64)) * 3
    y, aux = moe.apply(params, x, cfg)
    assert np.isfinite(np.asarray(y)).all()
    assert 0 <= float(aux["dropped_frac"]) <= 1


def test_frontend_fusion(key):
    cfg = tiny(("attn",) * 2, frontend="audio", frontend_tokens=4)
    m = Model(cfg)
    p = m.init(key)
    tok = jax.random.randint(jax.random.fold_in(key, 6), (2, 12), 0, 50)
    fe = jax.random.normal(jax.random.fold_in(key, 7), (2, 4, 64))
    a, _ = m.forward(p, tok, None, fe, causal=False)
    fe2 = fe.at[0, 0].add(1.0)
    b, _ = m.forward(p, tok, None, fe2, causal=False)
    assert float(jnp.abs(a - b).max()) > 1e-6   # embeddings actually used
    assert a.shape == (2, 12, 50)


def test_ring_buffer_decode_beyond_window(key):
    """SWA decode past the physical cache length stays consistent with a
    full forward (ring buffer correctness)."""
    W = 4
    cfg = tiny(("swa",), sliding_window=W)
    m = Model(cfg)
    p = m.init(key)
    S = 12
    tok = jax.random.randint(jax.random.fold_in(key, 8), (1, S), 0, 50)
    full, _ = m.forward(p, tok, None, causal=True)
    cache = m.init_cache(1, W)      # physical cache = window only
    outs = []
    for i in range(S):
        lg, cache = m.decode_step(p, tok[:, i:i + 1], cache, jnp.asarray(i))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-4, rtol=2e-3)


def test_param_counts():
    from repro.launch.analysis import param_counts
    import repro.configs as C
    m = Model(C.get("tinyllama-1.1b"))
    total, active = param_counts(m)
    assert abs(total - 1.1e9) / 1.1e9 < 0.05       # ~1.1B params
    mx = Model(C.get("mixtral-8x7b"))
    total, active = param_counts(mx)
    assert abs(total - 46.7e9) / 46.7e9 < 0.10     # ~47B total
    assert abs(active - 12.9e9) / 12.9e9 < 0.15    # ~13B active


def test_moe_local_dispatch_matches_global(key):
    """§Perf it1: per-group dispatch == global dispatch with ample cap."""
    cfg = tiny(("moe",), n_experts=4, experts_per_token=2,
               capacity_factor=16.0, moe_local_groups=4)
    params = moe.init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 9), (2, 16, 64))
    yg, ag = moe.apply(params, x, cfg)
    yl, al = moe.apply(params, x, cfg.replace(moe_dispatch="local"))
    np.testing.assert_allclose(np.asarray(yg), np.asarray(yl),
                               atol=1e-5, rtol=1e-5)
    assert float(al["dropped_frac"]) == 0.0


def test_mlstm_chunked_matches_parallel(key):
    """§Perf it1 (xlstm): chunkwise mLSTM == full parallel form."""
    from repro.models import xlstm
    cfg = tiny(("mlstm",), lstm_heads=2)
    p = xlstm.mlstm_init(key, cfg)
    u = jax.random.normal(jax.random.fold_in(key, 10), (2, 37, 64)) * 0.5
    full = xlstm.mlstm_apply(p, u, cfg)
    for chunk in (8, 16):
        for unroll in (False, True):
            c = xlstm.mlstm_apply(p, u, cfg.replace(
                mlstm_chunk=chunk, mlstm_unroll=unroll))
            np.testing.assert_allclose(np.asarray(full), np.asarray(c),
                                       atol=5e-5, rtol=5e-4)


def test_blocked_attention_unrolled_matches(key):
    cfg_a = tiny(("attn",) * 1, attn_impl="einsum")
    cfg_b = cfg_a.replace(attn_impl="blocked_unrolled", attn_block_k=8)
    tok = jax.random.randint(jax.random.fold_in(key, 11), (2, 24), 0, 50)
    ma, mb = Model(cfg_a), Model(cfg_b)
    p = ma.init(jax.random.PRNGKey(5))
    a, _ = ma.forward(p, tok, None, causal=True)
    b, _ = mb.forward(p, tok, None, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=3e-4, rtol=3e-3)


def test_moe_shard_map_paths_match_global(key):
    """shard_map dispatch (TP-psum and EP all-to-all) == global dispatch
    on a tiny host mesh (runs only when >= 8 devices are available —
    skipped in the default 1-device test env; exercised by
    launch/perf.py on the 512-device dry-run)."""
    if len(jax.device_count() * [0]) < 8:
        pytest.skip("needs 8 host devices (XLA_FLAGS)")
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    for n_experts in (8, 3):            # 8 -> EP path, 3 -> TP path
        cfg = tiny(("moe",), n_experts=n_experts, experts_per_token=2,
                   capacity_factor=16.0)
        params = moe.init(key, cfg)
        x = jax.random.normal(jax.random.fold_in(key, 12), (4, 16, 64))
        yg, _ = moe.apply(params, x, cfg)
        with jax.set_mesh(mesh):
            ys, _ = jax.jit(lambda p, x: moe.apply(
                p, x, cfg.replace(moe_dispatch="shard_map")))(params, x)
        np.testing.assert_allclose(np.asarray(yg), np.asarray(ys),
                                   atol=1e-5, rtol=1e-5)
