"""Sampler semantics: NFE laws, oracle recovery, equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import noise, schedules, transition
from repro.core.samplers import (SamplerConfig, d3pm, dndm, dndm_continuous,
                                 dndm_topk, mask_predict, rdm)

K, B, N, T = 24, 4, 16, 40
ARGMAX = SamplerConfig(x0_mode="argmax")


@pytest.fixture(scope="module")
def setup():
    sch = schedules.linear(T)
    dist = transition.from_schedule(sch)
    target = jax.random.randint(jax.random.PRNGKey(7), (B, N), 0, K - 1)

    def oracle(x_t, t, cond):
        return jax.nn.one_hot(target, K) * 25.0

    return sch, dist, target, oracle


@pytest.mark.parametrize("kind", ["absorbing", "multinomial"])
def test_dndm_oracle_recovery(setup, kind, key):
    sch, dist, target, oracle = setup
    nz = noise.get(kind, K)
    out = dndm.sample(key, oracle, nz, dist, B, N, cfg=ARGMAX)
    assert (out.tokens == target).all()
    assert out.nfe <= min(B * N, T)            # union over batch <= T
    # per-row NFE law
    per_row = np.asarray(transition.nfe_of(out.aux["tau"], T))
    assert np.all(per_row <= min(N, T))


def test_dndm_scan_equals_host_loop(setup, key):
    """The lax.cond-gated scan is the same algorithm as the host loop."""
    sch, dist, target, oracle = setup
    nz = noise.absorbing(K)
    a = dndm.sample(key, oracle, nz, dist, B, N, cfg=ARGMAX)
    b = dndm.sample_scan(key, oracle, nz, dist, B, N, cfg=ARGMAX)
    assert a.nfe == b.nfe
    assert (a.tokens == b.tokens).all()


def test_dndm_static_budget(setup, key):
    sch, dist, target, oracle = setup
    nz = noise.absorbing(K)
    for budget in (4, 10, 25):
        out = dndm.sample_static(key, oracle, nz, dist, B, N,
                                 nfe_budget=budget, cfg=ARGMAX)
        assert out.nfe == budget
        assert (out.tokens == target).all()


def test_static_grid_dedup_no_double_reveal(key):
    """Regression: with budget > |distinct quantile times| (small T or a
    concentrated D_tau) the quantile grid used to repeat times; the
    static scan then walked the duplicate, re-sampling every token
    bucketized onto it under a fresh step key — a second reveal of an
    already-revealed token.  The grid is deduped now: the actual NFE is
    ``len(grid) <= budget`` and any two budgets that dedupe to the same
    grid are bitwise-identical runs."""
    dist = transition.from_schedule(schedules.linear(3))
    nz = noise.absorbing(K)

    def net(x_t, t, cond):      # t-dependent: a re-run step changes tokens
        k = jnp.arange(K, dtype=jnp.float32)
        t_ = jnp.asarray(t, jnp.float32).reshape(-1, 1, 1)
        return jnp.sin(x_t[..., None].astype(jnp.float32) * 0.31
                       + k * 0.7 + t_ * 1.9) * 3.0

    grids = {b: dndm.quantile_grid(dist, b) for b in (3, 5, 9)}
    for g in grids.values():
        assert len(np.unique(g)) == len(g) <= 3
    np.testing.assert_array_equal(grids[5], grids[9])
    cfg = SamplerConfig(x0_mode="sample")
    outs = {b: dndm.sample_static(key, net, nz, dist, B, N, b, cfg=cfg)
            for b in (5, 9)}
    for b, out in outs.items():
        assert out.nfe == len(grids[b]) < b
    np.testing.assert_array_equal(np.asarray(outs[5].tokens),
                                  np.asarray(outs[9].tokens))


def test_dndm_absorbing_reveals_everything(setup, key):
    """No [MASK] left after a full reverse pass (Alg 1 invariant)."""
    sch, dist, target, oracle = setup
    nz = noise.absorbing(K)
    for version in (1, 2):
        out = dndm.sample(key, oracle, nz, dist, B, N, cfg=ARGMAX,
                          version=version)
        assert not (out.tokens == nz.mask_id).any()


def test_dndm_topk_nfe_matches_dndm(setup, key):
    sch, dist, target, oracle = setup
    nz = noise.absorbing(K)
    a = dndm.sample(key, oracle, nz, dist, B, N, cfg=ARGMAX)
    b = dndm_topk.sample(key, oracle, nz, dist, B, N, cfg=ARGMAX)
    assert a.nfe == b.nfe                      # same skip set (App. E)
    assert (b.tokens == target).all()


def test_dndm_continuous_nfe_is_N(setup, key):
    sch, dist, target, oracle = setup
    nz = noise.multinomial(K)
    cdist = transition.beta_continuous(17, 4)
    for topk in (False, True):
        out = dndm_continuous.sample(key, oracle, nz, cdist, B, N,
                                     cfg=ARGMAX, topk=topk)
        assert out.nfe == N                    # Remark 3.7 / Thm D.1 limit
        assert (out.tokens == target).all()


def test_baselines_nfe_is_T(setup, key):
    sch, dist, target, oracle = setup
    nz = noise.absorbing(K)
    assert d3pm.sample(key, oracle, nz, sch, B, N, cfg=ARGMAX).nfe == T
    assert rdm.sample(key, oracle, nz, sch, B, N, cfg=ARGMAX).nfe == T
    out = mask_predict.sample(key, oracle, nz, 10, B, N, cfg=ARGMAX)
    assert out.nfe == 10 and (out.tokens == target).all()


def test_d3pm_oracle_recovery(setup, key):
    sch, dist, target, oracle = setup
    for kind in ("absorbing", "multinomial"):
        nz = noise.get(kind, K)
        out = d3pm.sample(key, oracle, nz, sch, B, N, cfg=ARGMAX)
        assert (out.tokens == target).all(), kind


def test_rdm_oracle_recovery(setup, key):
    sch, dist, target, oracle = setup
    nz = noise.multinomial(K)
    for topk in (False, True):
        out = rdm.sample(key, oracle, nz, sch, B, N, cfg=ARGMAX, topk=topk)
        assert (out.tokens == target).all()


def test_dndm_reveal_order_l2r(setup, key):
    """l2r: leftmost tokens are revealed first in the reverse process."""
    sch, dist, target, oracle = setup
    nz = noise.absorbing(K)
    out = dndm.sample(key, oracle, nz, dist, B, N, cfg=SamplerConfig(
        x0_mode="argmax", trace=True), order="l2r")
    # in the trace, once position i is clean, all j < i are clean too
    for state in out.aux["trace"]:
        clean = state != nz.mask_id
        for b in range(B):
            idx = np.where(~clean[b])[0]
            if len(idx):
                assert clean[b, :idx[0]].all()


def test_mean_nfe_matches_thm_d1(setup):
    """Average per-row NFE over many draws ~ E|T| from Theorem D.1."""
    sch, dist, target, oracle = setup
    want = dist.expected_nfe(N)
    tau = transition.sample_transition_times(
        jax.random.PRNGKey(3), dist, 2000, N)
    got = float(np.mean(np.asarray(transition.nfe_of(tau, T))))
    assert abs(got - want) / want < 0.05


def test_ddim_oracle_recovery_and_stride(setup, key):
    """Discrete DDIM baseline: strided NFE = T/stride; oracle recovery."""
    from repro.core.samplers import ddim
    sch, dist, target, oracle = setup
    nz = noise.multinomial(K)
    for stride in (1, 2, 5):
        out = ddim.sample(key, oracle, nz, sch, B, N, stride=stride,
                          cfg=ARGMAX)
        assert out.nfe == -(-T // stride)
        assert (out.tokens == target).all(), stride
