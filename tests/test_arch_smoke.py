"""Deliverable (f): per-assigned-architecture smoke tests.

For each of the 10 assigned architectures: instantiate the REDUCED
same-family variant (<=2-3 layers, d_model<=512, <=4 experts) and run one
forward + one diffusion train step on CPU, asserting output shapes and
no NaNs.  Decode-capable archs also run a 4-token decode streak.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.core import noise, schedules
from repro.models import Model
from repro.models.frontend import fake_frontend_embeds
from repro.training import AdamW, constant, init_state, make_train_step

ARCHS = list(C.ASSIGNED_ARCHS)


@pytest.fixture(scope="module")
def schnz():
    return schedules.linear(20)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch, schnz, key):
    cfg = C.get(arch).reduced(bidirectional=True)
    model = Model(cfg)
    params = model.init(key)
    B, S = 2, 32
    tok = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0,
                             cfg.vocab_size - 1)
    fe = fake_frontend_embeds(jax.random.fold_in(key, 2), cfg, B)
    t = jnp.full((B,), 0.4)

    logits, aux = model.forward(params, tok, t, fe)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), arch

    nz = noise.absorbing(cfg.vocab_size)
    opt = AdamW(schedule=constant(1e-3))
    step = jax.jit(make_train_step(model, schnz, nz, opt))
    state = init_state(model, opt, jax.random.fold_in(key, 3))
    batch = {"x0": tok}
    if fe is not None:
        batch["frontend_embeds"] = fe
    state2, metrics = step(state, batch, jax.random.fold_in(key, 4))
    assert np.isfinite(float(metrics["loss"])), arch
    assert int(state2["step"]) == 1
    # params actually moved
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(state2["params"])))
    assert moved, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch, key):
    cfg = C.get(arch).reduced()          # causal serving mode
    model = Model(cfg)
    params = model.init(key)
    B = 2
    cache = model.init_cache(B, 16)
    tok = jax.random.randint(jax.random.fold_in(key, 5), (B, 4), 0,
                             cfg.vocab_size - 1)
    for i in range(4):
        logits, cache = model.decode_step(params, tok[:, i:i + 1], cache,
                                          jnp.asarray(i))
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all(), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    spec = {
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
    }[arch]
    cfg = C.get(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == spec, (arch, got, spec)
    # extras
    if arch == "zamba2-2.7b":
        assert cfg.ssm_state == 64 and "shared_attn" in cfg.block_pattern
    if arch == "mixtral-8x7b":
        assert (cfg.n_experts, cfg.experts_per_token) == (8, 2)
        assert cfg.sliding_window == 4096
    if arch == "llama4-maverick-400b-a17b":
        assert (cfg.n_experts, cfg.experts_per_token) == (128, 1)
    if arch == "xlstm-350m":
        assert {"mlstm", "slstm"} <= set(cfg.block_pattern)
    if arch in ("musicgen-large", "chameleon-34b"):
        assert cfg.frontend is not None and cfg.frontend_tokens > 0


def test_long_context_variant_subquadratic():
    for arch in ARCHS:
        cfg = C.for_long_context(C.get(arch))
        assert "attn" not in cfg.block_pattern, arch
        assert cfg.sliding_window > 0 or all(
            k in ("mamba2", "mlstm", "slstm", "shared_attn")
            for k in cfg.block_pattern)
