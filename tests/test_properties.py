"""Hypothesis property tests on system invariants (beyond the targeted
property tests embedded in the other files)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import noise, schedules, transition
from repro.core.samplers.dndm import quantile_grid
from repro.training import checkpoint


@given(T=st.integers(3, 300), K=st.integers(1, 64))
@settings(max_examples=25, deadline=None)
def test_quantile_grid_properties(T, K):
    """Grid is sorted, within {1..T}, ends at (or before) T, and covers
    the full transition mass (last grid point >= every tau quantile)."""
    dist = transition.from_schedule(schedules.cosine(T))
    K = min(K, T)
    grid = quantile_grid(dist, K)
    # deduped: at most K calls, strictly increasing (a repeated time would
    # make the static scan re-sample every token bucketized onto it)
    assert 1 <= len(grid) <= K
    assert np.all(np.diff(grid) > 0)
    assert 1 <= grid[0] and grid[-1] <= T
    cdf = np.cumsum(dist.probs)
    assert cdf[grid[-1] - 1] >= 1.0 - 1e-9


@given(seed=st.integers(0, 10_000), batch=st.integers(1, 6),
       N=st.integers(1, 40))
@settings(max_examples=20, deadline=None)
def test_shared_tau_is_constant_across_batch(seed, batch, N):
    dist = transition.from_schedule(schedules.linear(30))
    tau = transition.sample_transition_times(
        jax.random.PRNGKey(seed), dist, batch, N, shared=True)
    assert (np.asarray(tau) == np.asarray(tau)[0]).all()
    # iid draws must (almost surely) differ for a reasonable size
    if batch >= 4 and N >= 20:
        tau2 = transition.sample_transition_times(
            jax.random.PRNGKey(seed), dist, batch, N, shared=False)
        assert not (np.asarray(tau2) == np.asarray(tau2)[0]).all()


@given(seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_posterior_rows_normalized(seed):
    from repro.core.posterior import posterior
    key = jax.random.PRNGKey(seed)
    K = 9
    for kind in ("absorbing", "multinomial"):
        nz = noise.get(kind, K)
        x_t = jax.random.randint(key, (2, 7), 0, K)
        logits = jax.random.normal(jax.random.fold_in(key, 1), (2, 7, K))
        x0p = jax.nn.softmax(logits, -1)
        a = jax.random.uniform(jax.random.fold_in(key, 2), (2, 1),
                               minval=0.3, maxval=0.9)
        p = posterior(x_t, x0p, a, a * 0.5, nz)
        arr = np.asarray(p)
        np.testing.assert_allclose(arr.sum(-1), 1.0, atol=1e-4)
        assert (arr >= -1e-6).all()


@given(st.lists(st.tuples(st.integers(1, 4), st.integers(1, 5)),
                min_size=1, max_size=4),
       st.sampled_from(["float32", "bfloat16", "int32"]))
@settings(max_examples=15, deadline=None)
def test_checkpoint_roundtrip_random_trees(shapes, dtype):
    import tempfile
    tree = {f"k{i}": jnp.ones(s, jnp.dtype(dtype)) * i
            for i, s in enumerate(shapes)}
    tree["nested"] = {"list": [jnp.zeros((2,)),
                               {"deep": jnp.full((1, 2), 3.5)}]}
    path = tempfile.mkdtemp() + "/t"
    checkpoint.save(path, tree)
    back = checkpoint.load(path)
    la, lb = jax.tree.leaves(tree), jax.tree.leaves(back)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        assert str(a.dtype) == str(np.asarray(b).dtype)
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_microbatched_step_shapes_and_finiteness(key):
    from repro.core import schedules as sch_lib
    from repro.models import Model, ModelConfig
    from repro.training import AdamW, constant, init_state
    from repro.training.trainer import make_train_step
    cfg = ModelConfig(name="t", arch_type="dense", n_layers=1, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=30,
                      block_pattern=("attn",), bidirectional=True)
    model = Model(cfg)
    sch = sch_lib.linear(10)
    nz = noise.absorbing(30)
    opt = AdamW(schedule=constant(1e-3))
    state = init_state(model, opt, key)
    batch = {"x0": jax.random.randint(jax.random.fold_in(key, 1),
                                      (8, 12), 0, 29)}
    for k in (1, 2, 4):
        step = jax.jit(make_train_step(model, sch, nz, opt,
                                       microbatches=k))
        s, m = step(state, batch, jax.random.fold_in(key, 2))
        assert np.isfinite(float(m["loss"])), k
        assert int(s["step"]) == 1


@given(st.integers(0, 5_000), st.integers(2, 27))
@settings(max_examples=10, deadline=None)
def test_translate_is_invertible(seed, vocab):
    """The cipher translation is a bijection on token sequences."""
    from repro.data.synthetic import TranslationTask, translate
    task = TranslationTask(vocab, seed=seed)
    rng = np.random.default_rng(seed)
    src, tgt = task.sample_pairs(rng, 3, 20)
    inv = np.argsort(task.perm)
    np.testing.assert_array_equal(inv[tgt], src)


def test_bleu_sanity():
    from repro.data.synthetic import bleu
    a = np.arange(20)[None]
    assert bleu(a, a) > 99.0
    b = a + 100                     # disjoint tokens: no n-gram overlap
    assert bleu(b, a) < 1.0
    # reordering the same tokens keeps unigrams (beats disjoint) but the
    # geometric mean over 4-grams stays near zero
    c = a[:, ::-1]
    assert bleu(b, a) < bleu(c, a) < 99.0


# ------------------------------------------------------------------
# ISSUE 10: DDSketch quantile sketch (repro.obs.sketch)
# ------------------------------------------------------------------
from repro.obs.sketch import DDSketch

_positive = st.floats(min_value=1e-6, max_value=1e9,
                      allow_nan=False, allow_infinity=False)


@given(vals=st.lists(_positive, min_size=1, max_size=400),
       q=st.floats(0.0, 1.0))
@settings(max_examples=60, deadline=None)
def test_sketch_quantile_relative_error_bound(vals, q):
    """The DDSketch guarantee: quantile(q) is within alpha relative
    error of the true value at rank floor(q * (n - 1)) — the nearest-
    rank convention the sketch documents — for any value stream."""
    sk = DDSketch(alpha=0.01)
    for v in vals:
        sk.add(v)
    true = sorted(vals)[int(q * (len(vals) - 1))]
    assert abs(sk.quantile(q) - true) <= 0.01 * true * (1 + 1e-9)


@given(a=st.lists(_positive, max_size=100),
       b=st.lists(_positive, max_size=100),
       c=st.lists(_positive, max_size=100))
@settings(max_examples=40, deadline=None)
def test_sketch_merge_associative_commutative_exact(a, b, c):
    """merge is exact bucket addition: (A+B)+C == A+(B+C) == one global
    sketch over the concatenated stream, bins and zero/count state all
    equal — per-shard sketches lose nothing vs a single registry."""
    def mk(vals):
        s = DDSketch(alpha=0.01)
        for v in vals:
            s.add(v)
        return s

    left = mk(a).merge(mk(b)).merge(mk(c))           # (A+B)+C
    right = mk(a).merge(mk(b).merge(mk(c)))          # A+(B+C)
    flat = mk(a + b + c)                             # global
    swap = mk(c).merge(mk(a)).merge(mk(b))           # commuted
    for other in (right, flat, swap):
        assert left.bins == other.bins
        assert left.zeros == other.zeros
        assert left.count == other.count
    if flat.count:
        assert left.quantile(0.95) == flat.quantile(0.95)


@given(vals=st.lists(_positive, min_size=1, max_size=300))
@settings(max_examples=40, deadline=None)
def test_sketch_serialization_round_trip(vals):
    """to_dict/from_dict through actual JSON is lossless: every quantile
    answer survives — artifact readers see the live sketch."""
    import json as _json
    sk = DDSketch(alpha=0.01)
    for v in vals:
        sk.add(v)
    back = DDSketch.from_dict(_json.loads(_json.dumps(sk.to_dict())))
    assert back.bins == sk.bins and back.count == sk.count
    for q in (0.0, 0.5, 0.95, 0.99, 1.0):
        assert back.quantile(q) == sk.quantile(q)
