"""Run DNDM sampling on top of every assigned architecture family
(reduced configs on CPU): the paper's technique is backbone-agnostic.

    PYTHONPATH=src python examples/arch_zoo.py --arch zamba2-2.7b
    PYTHONPATH=src python examples/arch_zoo.py            # all ten
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.core import noise, schedules
from repro.models import Model
from repro.models.frontend import fake_frontend_embeds
from repro.serving import EngineConfig, GenerationEngine


def run_arch(arch: str, key) -> None:
    cfg = C.get(arch).reduced(bidirectional=True, vocab_size=64)
    model = Model(cfg)
    params = model.init(key)
    B, N = 2, 24
    cond = None
    if cfg.frontend:
        cond = {"frontend_embeds":
                fake_frontend_embeds(jax.random.fold_in(key, 1), cfg, B)}
    for method in ("dndm", "dndm_c"):
        eng = GenerationEngine(model, params, EngineConfig(
            method=method, steps=50,
            beta=(17, 4) if method == "dndm_c" else None))
        t0 = time.time()
        out, wall = eng.generate(key, B, N, cond=cond)
        ok = np.isfinite(np.asarray(out.tokens, np.float32)).all()
        print(f"  {arch:<28} {method:<8} nfe={out.nfe:<4} "
              f"wall={wall:6.2f}s tokens_ok={ok}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="one of %s or 'all'" % (C.list_archs(),))
    args = ap.parse_args()
    archs = C.ASSIGNED_ARCHS if args.arch == "all" else [args.arch]
    key = jax.random.PRNGKey(0)
    print("DNDM over the architecture zoo (reduced configs, random "
          "weights — demonstrates backbone-agnosticism):")
    for a in archs:
        run_arch(a, jax.random.fold_in(key, hash(a) % 2**31))


if __name__ == "__main__":
    main()
