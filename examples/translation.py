"""End-to-end conditional driver (the paper's MT experiment, synthetic):
train a denoiser on cipher-translation pairs for a few hundred steps,
then compare samplers on BLEU / NFE / wall — the shape of Tables 2/3.

    PYTHONPATH=src python examples/translation.py --steps 400

Scale up with --d-model 768 --layers 12 (~100M params) on real hardware.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import noise, schedules
from repro.data import DataConfig, DataPipeline
from repro.data.synthetic import bleu
from repro.models import Model, ModelConfig
from repro.serving import EngineConfig, GenerationEngine
from repro.training import AdamW, Trainer, warmup_cosine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--seq", type=int, default=24)
    ap.add_argument("--T", type=int, default=50)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--eval-batch", type=int, default=16)
    args = ap.parse_args()

    vocab = 28
    cfg = ModelConfig(
        name="mt-example", arch_type="dense", n_layers=args.layers,
        d_model=args.d_model, n_heads=max(4, args.d_model // 64),
        n_kv_heads=max(4, args.d_model // 64), d_ff=4 * args.d_model,
        vocab_size=vocab, block_pattern=("attn",) * args.layers,
        bidirectional=True)
    model = Model(cfg)
    print(f"params: {model.param_count(jax.eval_shape(model.init, jax.random.PRNGKey(0)))/1e6:.1f}M")
    sch = schedules.linear(args.T)
    nz = noise.absorbing(vocab)
    pipe = DataPipeline(DataConfig(task="translation", vocab=27,
                                   seq_len=args.seq, batch=32))

    print(f"== training ({args.steps} steps) ==")
    trainer = Trainer(model, sch, nz,
                      AdamW(schedule=warmup_cosine(3e-3, 20, args.steps)))
    state, _ = trainer.run(iter(pipe), steps=args.steps)

    ev = pipe.eval_batches(1)[0]
    B = args.eval_batch
    cond = {"prefix_tokens": jnp.asarray(ev["src"][:B])}
    ref = ev["x0"][:B]
    key = jax.random.PRNGKey(1)

    print(f"\n{'method':<16} {'steps':>6} {'NFE':>5} {'wall_s':>8} "
          f"{'BLEU':>7} {'tok_acc':>8}")
    for method in ("rdm", "rdm_k", "dndm", "dndm_topk", "dndm_c_topk"):
        for T in ((args.T,) if method != "dndm_c_topk" else ("inf",)):
            ec = EngineConfig(method=method,
                              steps=args.T if T == "inf" else T,
                              beta=(17, 4) if T == "inf" else None)
            eng = GenerationEngine(model, state["params"], ec)
            out, wall = eng.generate(key, B, args.seq, cond=cond)
            out, wall = eng.generate(key, B, args.seq, cond=cond)
            score = bleu(np.asarray(out.tokens), ref)
            acc = (np.asarray(out.tokens) == ref).mean()
            print(f"{method:<16} {T!s:>6} {out.nfe:>5} {wall:>8.3f} "
                  f"{score:>7.2f} {acc:>8.3f}")


if __name__ == "__main__":
    main()
