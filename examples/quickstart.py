"""Quickstart: train a small character-diffusion model and sample from it
with every member of the DNDM family vs the D3PM/RDM baselines.

    PYTHONPATH=src python examples/quickstart.py --steps 200

Prints a table of (sampler, NFE, wall seconds, perplexity-proxy).

Pass ``--metrics`` to turn on the runtime telemetry layer and print the
span/metric summary at the end (NFE counters, per-step reveal counts,
jit-cache hits, decode backend selection); ``REPRO_TRACE=path.jsonl``
additionally exports the full trace as JSON lines.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import obs
from repro.core import noise, schedules
from repro.core.samplers import registry
from repro.data import CharTokenizer, DataConfig, DataPipeline
from repro.models import Model, ModelConfig
from repro.serving import EngineConfig, GenerationEngine
from repro.training import AdamW, Trainer, warmup_cosine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--T", type=int, default=50)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--metrics", action="store_true",
                    help="enable repro.obs telemetry and print a summary")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve live metrics while running: Prometheus "
                         "text at /metrics, JSON at /snapshot (implies "
                         "--metrics; 0 picks an ephemeral port)")
    args = ap.parse_args()
    if args.metrics or args.metrics_port is not None:
        obs.enable()
    if args.metrics_port is not None:
        srv = obs.exporter.serve(args.metrics_port)
        print(f"live metrics: {srv.url}/metrics  |  {srv.url}/snapshot")

    vocab = 28                                     # 27 chars + [MASK]
    cfg = ModelConfig(
        name="quickstart", arch_type="dense", n_layers=args.layers,
        d_model=args.d_model, n_heads=4, n_kv_heads=2,
        d_ff=2 * args.d_model, vocab_size=vocab,
        block_pattern=("attn",) * args.layers, bidirectional=True)
    model = Model(cfg)
    sch = schedules.linear(args.T)
    nz = noise.absorbing(vocab)
    pipe = DataPipeline(DataConfig(task="unconditional", vocab=27,
                                   seq_len=args.seq, batch=32))

    print(f"== training {cfg.name} ({args.steps} steps) ==")
    trainer = Trainer(model, sch, nz,
                      AdamW(schedule=warmup_cosine(3e-3, 20, args.steps)))
    state, _ = trainer.run(iter(pipe), steps=args.steps)

    print("\n== sampling ==")
    tok = CharTokenizer()
    key = jax.random.PRNGKey(0)
    print(f"{'method':<16} {'NFE':>5} {'wall_s':>8} {'ppl_proxy':>10}")
    # every registered sampler that can run on the absorbing vocab —
    # new registry entries show up here with zero edits
    for method in registry.names(noise_kind="absorbing"):
        eng = GenerationEngine(model, state["params"], EngineConfig(
            method=method, steps=args.T, nfe_budget=12,
            beta=(17, 4) if method.startswith("dndm_c") else None))
        out, wall = eng.generate(key, 8, args.seq)
        out, wall = eng.generate(key, 8, args.seq)   # warm timing
        ll = pipe.lang.log_likelihood(np.asarray(out.tokens))
        print(f"{method:<16} {out.nfe:>5} {wall:>8.3f} "
              f"{np.exp(-ll):>10.2f}")
        if method == "dndm":
            print(f"  sample: {tok.decode(np.asarray(out.tokens)[0])!r}")

    if args.metrics or args.metrics_port is not None:
        # the telemetry roll-up: engine spans, per-step |R_t| histogram
        # with sketch-backed p50/p95/p99, jit-cache hit/miss counters,
        # decode backend selection
        print("\n== telemetry ==")
        print(obs.summary())


if __name__ == "__main__":
    main()
