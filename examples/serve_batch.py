"""Serving driver: batched generation requests through the scheduler —
the deployment shape of DNDM (static-quantile variant: one compiled
sampler, fixed NFE budget, requests packed into buckets).

    PYTHONPATH=src python examples/serve_batch.py --requests 24
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import noise, schedules
from repro.data import CharTokenizer, DataConfig, DataPipeline
from repro.models import Model, ModelConfig
from repro.serving import BatchScheduler, EngineConfig, GenerationEngine
from repro.training import AdamW, Trainer, warmup_cosine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--train-steps", type=int, default=150)
    ap.add_argument("--nfe-budget", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=8)
    args = ap.parse_args()

    vocab, seq = 28, 32
    cfg = ModelConfig(name="server", arch_type="dense", n_layers=2,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab_size=vocab, block_pattern=("attn",) * 2,
                      bidirectional=True)
    model = Model(cfg)
    sch = schedules.linear(50)
    nz = noise.absorbing(vocab)
    pipe = DataPipeline(DataConfig(task="unconditional", vocab=27,
                                   seq_len=seq, batch=32))
    trainer = Trainer(model, sch, nz,
                      AdamW(schedule=warmup_cosine(3e-3, 20,
                                                   args.train_steps)))
    state, _ = trainer.run(iter(pipe), steps=args.train_steps,
                           verbose=False)

    engine = GenerationEngine(model, state["params"], EngineConfig(
        method="dndm_topk_static", steps=50, nfe_budget=args.nfe_budget))
    sched = BatchScheduler(engine, max_batch=args.max_batch,
                           bucket_len=seq)

    t0 = time.time()
    ids = [sched.submit(seq) for _ in range(args.requests)]
    done = sched.run()
    wall = time.time() - t0
    tok = CharTokenizer()
    print(f"served {len(done)} requests in {wall:.2f}s "
          f"({len(done) / wall:.1f} req/s, NFE budget "
          f"{args.nfe_budget}/request-batch)")
    for rid in ids[:3]:
        print(f"  req {rid}: {tok.decode(done[rid].result)!r}")


if __name__ == "__main__":
    main()
