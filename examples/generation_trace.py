"""Paper Figure 2: visualize the DNDM reverse process — the text at
successive transition times and the quality trajectory.

    PYTHONPATH=src python examples/generation_trace.py --steps 100
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.core import noise, schedules, transition
from repro.core.samplers import SamplerConfig, dndm
from repro.data import CharTokenizer, DataConfig, DataPipeline
from repro.models import Model, ModelConfig
from repro.training import AdamW, Trainer, warmup_cosine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--train-steps", type=int, default=250)
    ap.add_argument("--seq", type=int, default=48)
    args = ap.parse_args()

    vocab = 28
    cfg = ModelConfig(name="trace", arch_type="dense", n_layers=2,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab_size=vocab, block_pattern=("attn",) * 2,
                      bidirectional=True)
    model = Model(cfg)
    sch = schedules.linear(args.steps)
    nz = noise.absorbing(vocab)
    pipe = DataPipeline(DataConfig(task="unconditional", vocab=27,
                                   seq_len=args.seq, batch=32))
    trainer = Trainer(model, sch, nz,
                      AdamW(schedule=warmup_cosine(3e-3, 20,
                                                   args.train_steps)))
    state, _ = trainer.run(iter(pipe), steps=args.train_steps,
                           verbose=False)

    dist = transition.beta_approx(args.steps, 15, 7)
    out = dndm.sample(
        jax.random.PRNGKey(0), model.denoise_fn(state["params"]), nz,
        dist, 1, args.seq, cfg=SamplerConfig(trace=True))
    tok = CharTokenizer()
    print(f"DNDM reverse process, T={args.steps}, NFE={out.nfe} "
          f"(one line per network call; '_' = [MASK]):\n")
    times = out.aux["times"]
    shown = 0
    for t, state_t in zip(times, out.aux["trace"]):
        row = state_t[0]
        text = "".join("_" if c == nz.mask_id else tok.alphabet[c]
                       for c in row)
        ll = pipe.lang.log_likelihood(
            np.where(row == nz.mask_id, 0, row))
        if shown % max(1, out.nfe // 12) == 0 or t == times[-1]:
            print(f"  t={t:4d}  ll/tok={ll:7.2f}  {text!r}")
        shown += 1
    print("\n(the majority of transitions cluster near the end of the "
          "reverse pass — the Beta(15,7) law from the paper's Fig. 2)")


if __name__ == "__main__":
    main()
